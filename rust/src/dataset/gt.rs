//! Exact ground truth via parallel brute force.
//!
//! Pure-Rust path (the AOT Pallas scan artifact offers the same computation
//! through [`crate::runtime`]; `anns::bruteforce` can use either — the two
//! are cross-checked in integration tests).

use crate::distance::Metric;
use crate::util::threadpool::parallel_map;

/// For each query, the indices of its `k` nearest base vectors (nearest
/// first, ties broken by lower index for determinism).
pub fn brute_force_topk(
    base: &[f32],
    queries: &[f32],
    dim: usize,
    metric: Metric,
    k: usize,
) -> Vec<Vec<u32>> {
    assert!(dim > 0);
    let n = base.len() / dim;
    let nq = queries.len() / dim;
    let k = k.min(n);
    parallel_map(nq, 1, |qi| {
        let q = &queries[qi * dim..(qi + 1) * dim];
        topk_for_query(base, q, dim, metric, k)
    })
}

/// Top-k scan for one query over a sorted-ascending bounded pool:
/// distances come in blocks from the one-to-many SIMD kernel (prefetch
/// pipelined), then O(k) insertion on improvement / O(1) rejection against
/// the current worst. Iteration order matches the plain scan, so results
/// (and tie-breaks) are identical to the per-pair path.
pub fn topk_for_query(base: &[f32], q: &[f32], dim: usize, metric: Metric, k: usize) -> Vec<u32> {
    let (mut ids, mut dists) = (Vec::new(), Vec::new());
    topk_pairs_for_query(base, q, dim, metric, k, &mut ids, &mut dists)
        .into_iter()
        .map(|(_, i)| i)
        .collect()
}

/// [`topk_for_query`] returning `(dist, id)` pairs and reusing
/// caller-provided block buffers — the blocked-scan body behind both the
/// ids-only ground-truth path and `BruteForceIndex`'s distance-carrying
/// batch search (which threads pooled scratch buffers through here so a
/// whole query batch allocates nothing but its result lists).
pub fn topk_pairs_for_query(
    base: &[f32],
    q: &[f32],
    dim: usize,
    metric: Metric,
    k: usize,
    ids: &mut Vec<u32>,
    dists: &mut Vec<f32>,
) -> Vec<(f32, u32)> {
    topk_pairs_for_query_filtered(base, q, dim, metric, k, ids, dists, |_| true)
}

/// [`topk_pairs_for_query`] restricted to rows the `live` predicate
/// accepts — how a mutable [`crate::anns::bruteforce::BruteForceIndex`]
/// keeps tombstoned/free slots out of its scan. The predicate is a
/// monomorphized generic, so the unfiltered path (`|_| true`) compiles to
/// exactly the pre-mutability blocked scan; iteration order is unchanged,
/// so tie-breaks match the per-pair path either way.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn topk_pairs_for_query_filtered(
    base: &[f32],
    q: &[f32],
    dim: usize,
    metric: Metric,
    k: usize,
    ids: &mut Vec<u32>,
    dists: &mut Vec<f32>,
    live: impl Fn(u32) -> bool,
) -> Vec<(f32, u32)> {
    let n = base.len() / dim;
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    const BLOCK: usize = 64;
    // (dist, idx) sorted ascending; pool.last() is the current worst.
    let mut pool: Vec<(f32, u32)> = Vec::with_capacity(k + 1);
    let mut start = 0usize;
    while start < n {
        let end = (start + BLOCK).min(n);
        ids.clear();
        ids.extend(start as u32..end as u32);
        metric.distance_batch(q, ids, base, dim, dists);
        for (&i, &d) in ids.iter().zip(dists.iter()) {
            if !live(i) {
                continue;
            }
            let cand = (d, i);
            if pool.len() == k && cmp_asc(&cand, pool.last().unwrap()) != std::cmp::Ordering::Less
            {
                continue;
            }
            let pos = pool
                .binary_search_by(|probe| cmp_asc(probe, &cand))
                .unwrap_or_else(|p| p);
            pool.insert(pos, cand);
            if pool.len() > k {
                pool.pop();
            }
        }
        start = end;
    }
    pool
}

fn cmp_asc(a: &(f32, u32), b: &(f32, u32)) -> std::cmp::Ordering {
    a.0.partial_cmp(&b.0)
        .unwrap_or(std::cmp::Ordering::Equal)
        .then(a.1.cmp(&b.1))
}

/// recall@k of `found` against exact `gt` (both nearest-first id lists).
pub fn recall_at_k(found: &[u32], gt: &[u32], k: usize) -> f64 {
    let k = k.min(gt.len());
    if k == 0 {
        return 1.0;
    }
    let gtset: std::collections::HashSet<u32> = gt[..k].iter().copied().collect();
    let hits = found.iter().take(k).filter(|i| gtset.contains(i)).count();
    hits as f64 / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matches_full_sort() {
        let dim = 16;
        let n = 300;
        let mut rng = Rng::new(1);
        let base: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
        let queries: Vec<f32> = (0..5 * dim).map(|_| rng.next_gaussian_f32()).collect();
        for metric in [Metric::L2, Metric::Ip] {
            let got = brute_force_topk(&base, &queries, dim, metric, 10);
            for qi in 0..5 {
                let q = &queries[qi * dim..(qi + 1) * dim];
                let mut all: Vec<(f32, u32)> = (0..n)
                    .map(|i| (metric.distance(q, &base[i * dim..(i + 1) * dim]), i as u32))
                    .collect();
                all.sort_by(super::cmp_asc);
                let want: Vec<u32> = all.iter().take(10).map(|x| x.1).collect();
                assert_eq!(got[qi], want, "metric={metric:?} q={qi}");
            }
        }
    }

    #[test]
    fn k_larger_than_n() {
        let base = vec![0.0, 1.0, 2.0, 3.0]; // 4 scalars dim=1
        let q = vec![0.9];
        let got = brute_force_topk(&base, &q, 1, Metric::L2, 10);
        assert_eq!(got[0], vec![1, 0, 2, 3]);
    }

    #[test]
    fn filtered_scan_equals_scan_of_live_subset() {
        let dim = 8;
        let n = 200;
        let mut rng = Rng::new(4);
        let base: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
        let dead: std::collections::HashSet<u32> =
            (0..n as u32).filter(|_| rng.next_f64() < 0.3).collect();
        let (mut ids, mut dists) = (Vec::new(), Vec::new());
        let got = topk_pairs_for_query_filtered(
            &base,
            &q,
            dim,
            Metric::L2,
            10,
            &mut ids,
            &mut dists,
            |i| !dead.contains(&i),
        );
        let mut all: Vec<(f32, u32)> = (0..n as u32)
            .filter(|i| !dead.contains(i))
            .map(|i| {
                let r = &base[i as usize * dim..(i as usize + 1) * dim];
                (Metric::L2.distance(&q, r), i)
            })
            .collect();
        all.sort_by(super::cmp_asc);
        all.truncate(10);
        assert_eq!(got, all);
        assert!(got.iter().all(|&(_, i)| !dead.contains(&i)));
        // The constant-true predicate is exactly the unfiltered scan.
        let plain =
            topk_pairs_for_query(&base, &q, dim, Metric::L2, 10, &mut ids, &mut dists);
        let always = topk_pairs_for_query_filtered(
            &base,
            &q,
            dim,
            Metric::L2,
            10,
            &mut ids,
            &mut dists,
            |_| true,
        );
        assert_eq!(plain, always);
    }

    #[test]
    fn recall_computation() {
        assert_eq!(recall_at_k(&[1, 2, 3], &[1, 2, 3], 3), 1.0);
        assert_eq!(recall_at_k(&[1, 9, 8], &[1, 2, 3], 3), 1.0 / 3.0);
        assert_eq!(recall_at_k(&[], &[1, 2], 2), 0.0);
        assert_eq!(recall_at_k(&[7], &[], 0), 1.0);
    }

    #[test]
    fn deterministic_ties() {
        // Identical points: lower index wins.
        let base = vec![1.0, 1.0, 1.0, 2.0]; // dim=1: [1,1,1,2]
        let q = vec![1.0];
        let got = brute_force_topk(&base, &q, 1, Metric::L2, 3);
        assert_eq!(got[0], vec![0, 1, 2]);
    }
}
