//! Local intrinsic dimension (LID) estimation — Table 2's `LID` column.
//!
//! Levina–Bickel maximum-likelihood estimator: for a point x with sorted
//! neighbor distances r_1 <= … <= r_k,
//!
//! `lid(x) = ( (1/(k-1)) * Σ_{i<k} ln(r_k / r_i) )^{-1}`
//!
//! averaged over a random sample of base points. Distances use the true
//! Euclidean (sqrt of our squared-L2) or angular distance, matching how
//! ann-benchmarks reports the column.

use crate::distance::Metric;
use crate::util::rng::Rng;

/// Estimate the dataset's average LID from `sample` random points with
/// `k` neighbors each.
pub fn estimate_lid(
    base: &[f32],
    dim: usize,
    metric: Metric,
    k: usize,
    sample: usize,
    seed: u64,
) -> f64 {
    assert!(dim > 0 && k >= 2);
    let n = base.len() / dim;
    if n < k + 2 {
        return f64::NAN;
    }
    let mut rng = Rng::new(seed);
    let picks = rng.sample_indices(n, sample.min(n));
    let mut acc = 0.0;
    let mut cnt = 0usize;
    for &pi in &picks {
        let q = &base[pi * dim..(pi + 1) * dim];
        // k+1 nearest including self; drop the self (distance 0).
        let ids = crate::dataset::gt::topk_for_query(base, q, dim, metric, k + 1);
        let mut dists: Vec<f64> = ids
            .iter()
            .filter(|&&i| i as usize != pi)
            .map(|&i| {
                let d = metric.distance(q, &base[i as usize * dim..(i as usize + 1) * dim]);
                match metric {
                    Metric::L2 => (d.max(0.0) as f64).sqrt(),
                    _ => (d as f64).max(0.0),
                }
            })
            .collect();
        dists.truncate(k);
        if dists.len() < k {
            continue;
        }
        dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rk = dists[k - 1];
        if rk <= 0.0 {
            continue;
        }
        let mut s = 0.0;
        let mut ok = true;
        for &ri in &dists[..k - 1] {
            if ri <= 0.0 {
                ok = false;
                break;
            }
            s += (rk / ri).ln();
        }
        if !ok || s <= 0.0 {
            continue;
        }
        acc += (k as f64 - 1.0) / s;
        cnt += 1;
    }
    if cnt == 0 {
        f64::NAN
    } else {
        acc / cnt as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Points uniform in a d-dim ball embedded in higher dim: LID ≈ d.
    fn ball_embedded(n: usize, d_int: usize, d_amb: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut out = vec![0f32; n * d_amb];
        for i in 0..n {
            // Gaussian direction, radius ~ U^{1/d}: uniform in the ball.
            let mut v: Vec<f32> = (0..d_int).map(|_| rng.next_gaussian_f32()).collect();
            let nv = crate::distance::norm(&v);
            let r = rng.next_f64().powf(1.0 / d_int as f64) as f32;
            for x in v.iter_mut() {
                *x = *x / nv.max(1e-9) * r;
            }
            out[i * d_amb..i * d_amb + d_int].copy_from_slice(&v);
        }
        out
    }

    #[test]
    fn recovers_intrinsic_dim_roughly() {
        for &d_int in &[3usize, 8] {
            let data = ball_embedded(3000, d_int, 32, 9);
            let lid = estimate_lid(&data, 32, Metric::L2, 20, 150, 4);
            assert!(
                (lid - d_int as f64).abs() < d_int as f64 * 0.6 + 1.0,
                "d_int={d_int} estimated LID={lid}"
            );
        }
    }

    #[test]
    fn monotone_in_intrinsic_dim() {
        let a = estimate_lid(&ball_embedded(2000, 3, 24, 1), 24, Metric::L2, 15, 100, 2);
        let b = estimate_lid(&ball_embedded(2000, 12, 24, 1), 24, Metric::L2, 15, 100, 2);
        assert!(b > a, "lid(3)={a} lid(12)={b}");
    }

    #[test]
    fn degenerate_inputs() {
        // Too few points -> NaN, not panic.
        let lid = estimate_lid(&[0.0; 8], 2, Metric::L2, 4, 10, 0);
        assert!(lid.is_nan());
    }
}
