//! Offline stub for the `xla`/PJRT bindings.
//!
//! The real runtime binds the `xla` crate (xla-rs over `xla_extension`),
//! whose native library cannot be vendored into this zero-dependency
//! offline build (DESIGN.md §8, §Hardware-Adaptation). This module mirrors
//! the exact API surface [`crate::runtime::engine`] consumes so the crate
//! compiles and tests everywhere; at runtime, [`PjRtClient::cpu`] reports
//! the backend as unavailable and every engine-dependent path degrades
//! gracefully (the trainer/bench targets print a skip notice, tests
//! gate on `artifacts/manifest.json`).
//!
//! Swapping in the real backend is a two-line change: add the `xla`
//! dependency and point `use crate::runtime::xla;` in `engine.rs` at the
//! external crate instead.

use std::fmt;

/// Error type for the stub backend; implements `std::error::Error` so the
/// engine's `.context(...)` calls work unchanged against the real crate.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(
        "XLA/PJRT backend not built into this binary (offline stub; see \
         DESIGN.md §Hardware-Adaptation) — engine paths require the real \
         `xla` bindings plus `make artifacts`"
            .to_string(),
    ))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    /// The real binding constructs a CPU PJRT client; the stub always
    /// reports the backend as unavailable.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host tensor (stub).
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn scalar(_v: f32) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not succeed");
        assert!(err.to_string().contains("offline stub"));
    }

    #[test]
    fn engine_construction_fails_cleanly_without_backend() {
        // Engine::new goes through Manifest::load first; point it at a
        // directory with a valid manifest-shaped file to reach the client.
        let dir = std::env::temp_dir().join(format!("crinn_xla_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"query_batch":64,"base_block":4096,"rerank_cands":128,
                "n_knobs":8,"n_exemplars":4,"n_modules":3,"feat_dim":40,
                "hidden":64,"group":8,"param_shapes":[],"dims":[128],
                "artifacts":{},"init_params":[]}"#,
        )
        .unwrap();
        let err = crate::runtime::Engine::new(&dir).err().expect("no backend");
        assert!(format!("{err:#}").contains("PJRT"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
