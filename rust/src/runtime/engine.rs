//! PJRT execution engine.
//!
//! Compiles HLO-text artifacts lazily (first use) and caches the loaded
//! executables. Exposes the three batch entry points the coordinator and
//! trainer need:
//! * [`Engine::scan`] — `[QB, D] x [BB, D] -> [QB, BB]` distance blocks
//!   (brute-force ground truth / IVF coarse scoring);
//! * [`Engine::rerank`] — `[QB, D] x [QB, C, D] -> [QB, C]` exact
//!   refinement distances for gathered candidates;
//! * [`Engine::policy_forward`] / [`Engine::grpo_step`] — the CRINN policy
//!   network and its fused GRPO+Adam update (Eq. 3).
//!
//! All inputs are padded to the compiled shapes; helpers slice the valid
//! region back out.

use crate::distance::Metric;
use crate::runtime::manifest::Manifest;
use crate::runtime::xla;
use crate::util::error::{Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// Cached PJRT client + compiled executables.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

// SAFETY: the xla crate's client/executable wrap thread-safe XLA objects;
// the raw pointers lack auto-impls. Access is serialized through &self and
// the executables are internally synchronized by PJRT's CPU client.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create from an artifacts directory (see [`crate::runtime::artifacts_dir`]).
    pub fn new(dir: &std::path::Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Engine {
            manifest,
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Create from the default artifacts location.
    pub fn from_default_artifacts() -> Result<Engine> {
        Engine::new(&crate::runtime::artifacts_dir())
    }

    /// Load + compile an artifact (cached).
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let path = self.manifest.artifact_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path utf8")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {name}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on f32 tensors; returns the flattened outputs.
    /// `inputs` are `(data, dims)`; the lowered modules return tuples.
    pub fn run_f32(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let exe = self.executable(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let n: usize = dims.iter().product();
                crate::ensure!(
                    data.len() == n,
                    "input size {} != shape {:?} for {name}",
                    data.len(),
                    dims
                );
                let lit = xla::Literal::vec1(data);
                if dims.len() == 1 {
                    Ok(lit)
                } else if dims.is_empty() {
                    // 0-d scalar.
                    Ok(xla::Literal::scalar(data[0]))
                } else {
                    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                    lit.reshape(&d).map_err(Into::into)
                }
            })
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(Into::into))
            .collect()
    }

    // -- Batch distance paths -------------------------------------------

    fn metric_tag(metric: Metric) -> &'static str {
        match metric {
            Metric::L2 => "l2",
            // The angular artifact computes 1 - q·b; Ip reuses it shifted.
            Metric::Angular | Metric::Ip => "angular",
        }
    }

    /// Distance block: queries `[nq, dim]` (nq <= query_batch) against a
    /// base block `[nb, dim]` (nb <= base_block). Returns `[nq][nb]`.
    pub fn scan(
        &self,
        metric: Metric,
        queries: &[f32],
        nq: usize,
        base: &[f32],
        nb: usize,
        dim: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let qb = self.manifest.query_batch;
        let bb = self.manifest.base_block;
        crate::ensure!(nq <= qb && nb <= bb, "batch too large ({nq}x{nb})");
        crate::ensure!(self.manifest.has_dim(dim), "no artifact for dim {dim}");
        let name = format!("scan_{}_d{}", Self::metric_tag(metric), dim);
        let mut qpad = vec![0f32; qb * dim];
        qpad[..nq * dim].copy_from_slice(&queries[..nq * dim]);
        let mut bpad = vec![0f32; bb * dim];
        bpad[..nb * dim].copy_from_slice(&base[..nb * dim]);
        let out = self.run_f32(&name, &[(&qpad, &[qb, dim]), (&bpad, &[bb, dim])])?;
        let flat = &out[0];
        let shift = matches!(metric, Metric::Ip); // -q·b = (1 - q·b) - 1
        Ok((0..nq)
            .map(|qi| {
                flat[qi * bb..qi * bb + nb]
                    .iter()
                    .map(|&d| if shift { d - 1.0 } else { d })
                    .collect()
            })
            .collect())
    }

    /// Exact top-k over the whole base via blocked scans (the PJRT
    /// brute-force path; cross-checked against `dataset::gt` in tests).
    pub fn brute_force_topk(
        &self,
        metric: Metric,
        queries: &[f32],
        base: &[f32],
        dim: usize,
        k: usize,
    ) -> Result<Vec<Vec<u32>>> {
        let nq_total = queries.len() / dim;
        let n = base.len() / dim;
        let qb = self.manifest.query_batch;
        let bb = self.manifest.base_block;
        let mut out = Vec::with_capacity(nq_total);
        for q0 in (0..nq_total).step_by(qb) {
            let nq = (nq_total - q0).min(qb);
            let mut pools: Vec<crate::anns::heap::TopK> =
                (0..nq).map(|_| crate::anns::heap::TopK::new(k.min(n).max(1))).collect();
            for b0 in (0..n).step_by(bb) {
                let nb = (n - b0).min(bb);
                let block = self.scan(
                    metric,
                    &queries[q0 * dim..(q0 + nq) * dim],
                    nq,
                    &base[b0 * dim..(b0 + nb) * dim],
                    nb,
                    dim,
                )?;
                for (qi, row) in block.iter().enumerate() {
                    for (bi, &d) in row.iter().enumerate() {
                        pools[qi].push(d, (b0 + bi) as u32);
                    }
                }
            }
            for p in pools {
                out.push(p.into_sorted().into_iter().map(|(_, i)| i).collect());
            }
        }
        Ok(out)
    }

    /// Rerank gathered candidates: `queries [nq, dim]`, `cands [nq, c, dim]`
    /// with `nq <= query_batch`, `c <= rerank_cands`. Returns `[nq][c]`.
    pub fn rerank(
        &self,
        metric: Metric,
        queries: &[f32],
        nq: usize,
        cands: &[f32],
        c: usize,
        dim: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let qb = self.manifest.query_batch;
        let rc = self.manifest.rerank_cands;
        crate::ensure!(nq <= qb && c <= rc, "rerank batch too large ({nq}x{c})");
        crate::ensure!(self.manifest.has_dim(dim), "no artifact for dim {dim}");
        let name = format!("rerank_{}_d{}", Self::metric_tag(metric), dim);
        let mut qpad = vec![0f32; qb * dim];
        qpad[..nq * dim].copy_from_slice(&queries[..nq * dim]);
        let mut cpad = vec![0f32; qb * rc * dim];
        for qi in 0..nq {
            let src = &cands[qi * c * dim..(qi + 1) * c * dim];
            cpad[qi * rc * dim..qi * rc * dim + c * dim].copy_from_slice(src);
        }
        let out = self.run_f32(&name, &[(&qpad, &[qb, dim]), (&cpad, &[qb, rc, dim])])?;
        let flat = &out[0];
        let shift = matches!(metric, Metric::Ip);
        Ok((0..nq)
            .map(|qi| {
                flat[qi * rc..qi * rc + c]
                    .iter()
                    .map(|&d| if shift { d - 1.0 } else { d })
                    .collect()
            })
            .collect())
    }

    // -- Policy / GRPO paths --------------------------------------------

    /// Policy forward: params (7 tensors) + features `[G, F]` ->
    /// `(mean [G, A], logstd [G, A])`.
    pub fn policy_forward(
        &self,
        params: &[Vec<f32>],
        feats: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let m = &self.manifest;
        crate::ensure!(params.len() == m.param_shapes.len(), "param arity");
        crate::ensure!(feats.len() == m.group * m.feat_dim, "feature shape");
        let mut inputs: Vec<(&[f32], Vec<usize>)> = params
            .iter()
            .enumerate()
            .map(|(i, p)| (p.as_slice(), m.param_shapes[i].1.clone()))
            .collect();
        inputs.push((feats, vec![m.group, m.feat_dim]));
        let refs: Vec<(&[f32], &[usize])> =
            inputs.iter().map(|(d, s)| (*d, s.as_slice())).collect();
        let out = self.run_f32("policy_fwd", &refs)?;
        crate::ensure!(out.len() == 2, "policy_fwd outputs");
        Ok((out[0].clone(), out[1].clone()))
    }

    /// One fused GRPO update. Returns `(new_params, new_m, new_v, loss)`.
    #[allow(clippy::too_many_arguments)]
    pub fn grpo_step(
        &self,
        params: &[Vec<f32>],
        adam_m: &[Vec<f32>],
        adam_v: &[Vec<f32>],
        ref_params: &[Vec<f32>],
        feats: &[f32],
        actions: &[f32],
        advantages: &[f32],
        old_logp: &[f32],
        lr: f32,
        clip_eps: f32,
        kl_beta: f32,
        t: f32,
    ) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>, f32)> {
        let m = &self.manifest;
        let np = m.param_shapes.len();
        let scalars = [lr, clip_eps, kl_beta, t];
        let mut inputs: Vec<(&[f32], Vec<usize>)> = Vec::with_capacity(4 * np + 8);
        for group in [params, adam_m, adam_v, ref_params] {
            crate::ensure!(group.len() == np, "param group arity");
            for (i, p) in group.iter().enumerate() {
                inputs.push((p.as_slice(), m.param_shapes[i].1.clone()));
            }
        }
        inputs.push((feats, vec![m.group, m.feat_dim]));
        inputs.push((actions, vec![m.group, m.n_knobs]));
        inputs.push((advantages, vec![m.group]));
        inputs.push((old_logp, vec![m.group]));
        for s in &scalars {
            inputs.push((std::slice::from_ref(s), vec![]));
        }
        let refs: Vec<(&[f32], &[usize])> =
            inputs.iter().map(|(d, s)| (*d, s.as_slice())).collect();
        let out = self.run_f32("grpo_step", &refs)?;
        crate::ensure!(out.len() == 3 * np + 1, "grpo_step outputs {}", out.len());
        let new_params = out[..np].to_vec();
        let new_m = out[np..2 * np].to_vec();
        let new_v = out[2 * np..3 * np].to_vec();
        let loss = out[3 * np][0];
        Ok((new_params, new_m, new_v, loss))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn engine() -> Option<Engine> {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        match Engine::new(&dir) {
            Ok(e) => Some(e),
            Err(e) if format!("{e:#}").contains("offline stub") => {
                eprintln!("skipping: PJRT backend is the offline stub");
                None
            }
            Err(e) => panic!("engine failed with artifacts present: {e:#}"),
        }
    }

    #[test]
    fn scan_matches_rust_distances() {
        let Some(e) = engine() else { return };
        let dim = 64;
        let mut rng = Rng::new(1);
        let q: Vec<f32> = (0..5 * dim).map(|_| rng.next_gaussian_f32()).collect();
        let b: Vec<f32> = (0..37 * dim).map(|_| rng.next_gaussian_f32()).collect();
        let got = e.scan(Metric::L2, &q, 5, &b, 37, dim).unwrap();
        for qi in 0..5 {
            for bi in 0..37 {
                let want =
                    crate::distance::l2_sq(&q[qi * dim..(qi + 1) * dim], &b[bi * dim..(bi + 1) * dim]);
                assert!(
                    (got[qi][bi] - want).abs() < 1e-2 * (1.0 + want.abs()),
                    "q{qi} b{bi}: {} vs {want}",
                    got[qi][bi]
                );
            }
        }
    }

    #[test]
    fn brute_force_topk_matches_rust_gt() {
        let Some(e) = engine() else { return };
        let dim = 64;
        let mut rng = Rng::new(2);
        let base: Vec<f32> = (0..500 * dim).map(|_| rng.next_gaussian_f32()).collect();
        let q: Vec<f32> = (0..3 * dim).map(|_| rng.next_gaussian_f32()).collect();
        let got = e.brute_force_topk(Metric::L2, &q, &base, dim, 10).unwrap();
        let want = crate::dataset::gt::brute_force_topk(&base, &q, dim, Metric::L2, 10);
        assert_eq!(got, want);
    }

    #[test]
    fn rerank_matches_rust_distances() {
        let Some(e) = engine() else { return };
        let dim = 64;
        let mut rng = Rng::new(3);
        let nq = 4;
        let c = 17;
        let q: Vec<f32> = (0..nq * dim).map(|_| rng.next_gaussian_f32()).collect();
        let cands: Vec<f32> = (0..nq * c * dim).map(|_| rng.next_gaussian_f32()).collect();
        let got = e.rerank(Metric::L2, &q, nq, &cands, c, dim).unwrap();
        for qi in 0..nq {
            for ci in 0..c {
                let want = crate::distance::l2_sq(
                    &q[qi * dim..(qi + 1) * dim],
                    &cands[(qi * c + ci) * dim..(qi * c + ci + 1) * dim],
                );
                assert!(
                    (got[qi][ci] - want).abs() < 1e-2 * (1.0 + want.abs()),
                    "{} vs {want}",
                    got[qi][ci]
                );
            }
        }
    }

    #[test]
    fn policy_forward_shapes_and_determinism() {
        let Some(e) = engine() else { return };
        let m = &e.manifest;
        let params = m.init_params.clone();
        let feats = vec![0.1f32; m.group * m.feat_dim];
        let (mean, logstd) = e.policy_forward(&params, &feats).unwrap();
        assert_eq!(mean.len(), m.group * m.n_knobs);
        assert_eq!(logstd.len(), m.group * m.n_knobs);
        assert!(mean.iter().all(|x| x.abs() <= 1.0 + 1e-5));
        let (mean2, _) = e.policy_forward(&params, &feats).unwrap();
        assert_eq!(mean, mean2);
    }

    #[test]
    fn grpo_step_updates_params_toward_advantage() {
        let Some(e) = engine() else { return };
        let m = &e.manifest;
        let mut params = m.init_params.clone();
        let zeros: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let mut adam_m = zeros.clone();
        let mut adam_v = zeros;
        let refp = params.clone();
        let mut rng = Rng::new(4);
        let feats: Vec<f32> = (0..m.group * m.feat_dim)
            .map(|_| rng.next_gaussian_f32() * 0.3)
            .collect();
        let actions: Vec<f32> = (0..m.group * m.n_knobs)
            .map(|_| (rng.next_f32() - 0.5).clamp(-1.0, 1.0))
            .collect();
        let mut adv = vec![-0.5f32; m.group];
        adv[0] = 2.0;
        // old_logp from the initial policy (ratio starts at 1).
        let (mean, logstd) = e.policy_forward(&params, &feats).unwrap();
        let old_logp: Vec<f32> = (0..m.group)
            .map(|g| {
                (0..m.n_knobs)
                    .map(|a| {
                        let mu = mean[g * m.n_knobs + a];
                        let ls = logstd[g * m.n_knobs + a];
                        let var = (2.0 * ls).exp();
                        let x = actions[g * m.n_knobs + a];
                        -0.5 * ((x - mu) * (x - mu) / var
                            + 2.0 * ls
                            + (2.0 * std::f32::consts::PI).ln())
                    })
                    .sum()
            })
            .collect();
        let mut last_loss = f32::INFINITY;
        for t in 1..=5 {
            let (np, nm, nv, loss) = e
                .grpo_step(
                    &params, &adam_m, &adam_v, &refp, &feats, &actions, &adv, &old_logp,
                    0.01, 0.2, 0.01, t as f32,
                )
                .unwrap();
            params = np;
            adam_m = nm;
            adam_v = nv;
            assert!(loss.is_finite());
            last_loss = loss;
        }
        assert!(last_loss.is_finite());
        // Params actually moved.
        let delta: f32 = params
            .iter()
            .zip(&refp)
            .map(|(a, b)| a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f32>())
            .sum();
        assert!(delta > 1e-4, "params did not move: {delta}");
    }
}
