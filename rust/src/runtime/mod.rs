//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `python/compile/aot.py`) and executes them on the CPU PJRT
//! client. This is the only bridge between the Rust request path and the
//! JAX/Pallas compute — Python never runs here.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md §Hardware-Adaptation).
//!
//! This build links the offline [`xla`] stub in place of the real PJRT
//! bindings, so [`Engine`] construction reports the backend as
//! unavailable; every caller handles that path (DESIGN.md §8).

pub mod engine;
pub mod manifest;
pub mod xla;

pub use engine::Engine;
pub use manifest::Manifest;

/// Default artifacts directory (overridable with `CRINN_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("CRINN_ARTIFACTS") {
        return p.into();
    }
    // Walk up from cwd looking for artifacts/manifest.json (works from
    // target/, examples, tests).
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
