//! `artifacts/manifest.json` — the shape contract between `aot.py` and the
//! Rust runtime. Single source of truth for batch shapes, policy network
//! dimensions, and the initial policy parameters.

use crate::util::error::{Context, Error, Result};
use crate::util::json::{parse, Json};
use std::path::{Path, PathBuf};

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// Rows per scan/rerank call (queries padded to this).
    pub query_batch: usize,
    /// Base vectors per scan block.
    pub base_block: usize,
    /// Candidates per query in the rerank artifact.
    pub rerank_cands: usize,
    pub n_knobs: usize,
    pub n_exemplars: usize,
    pub n_modules: usize,
    pub feat_dim: usize,
    pub hidden: usize,
    pub group: usize,
    /// `(name, shape)` for each policy parameter tensor, in PJRT order.
    pub param_shapes: Vec<(String, Vec<usize>)>,
    /// Vector dims with compiled scan/rerank artifacts.
    pub dims: Vec<usize>,
    /// artifact name -> file name.
    pub artifacts: std::collections::BTreeMap<String, String>,
    /// Flat initial policy parameters (PJRT order).
    pub init_params: Vec<Vec<f32>>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let raw = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read {dir:?}/manifest.json — run `make artifacts`"))?;
        let j = parse(&raw).map_err(Error::msg)?;
        let u = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest field {k}"))
        };
        let param_shapes = j
            .get("param_shapes")
            .and_then(Json::as_arr)
            .context("param_shapes")?
            .iter()
            .map(|e| {
                let a = e.as_arr().context("param shape entry")?;
                let name = a[0].as_str().context("param name")?.to_string();
                let shape = a[1]
                    .as_arr()
                    .context("param dims")?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                Ok((name, shape))
            })
            .collect::<Result<Vec<_>>>()?;
        let artifacts = j
            .get("artifacts")
            .map(|a| match a {
                Json::Obj(m) => m
                    .iter()
                    .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                    .collect(),
                _ => Default::default(),
            })
            .unwrap_or_default();
        let init_params = j
            .get("init_params")
            .and_then(Json::as_arr)
            .context("init_params")?
            .iter()
            .map(|p| {
                p.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_f64().map(|f| f as f32))
                    .collect()
            })
            .collect();
        Ok(Manifest {
            dir: dir.to_path_buf(),
            query_batch: u("query_batch")?,
            base_block: u("base_block")?,
            rerank_cands: u("rerank_cands")?,
            n_knobs: u("n_knobs")?,
            n_exemplars: u("n_exemplars")?,
            n_modules: u("n_modules")?,
            feat_dim: u("feat_dim")?,
            hidden: u("hidden")?,
            group: u("group")?,
            param_shapes,
            dims: j
                .get("dims")
                .and_then(Json::as_arr)
                .context("dims")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            artifacts,
            init_params,
        })
    }

    /// Path of an artifact by name.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let f = self
            .artifacts
            .get(name)
            .with_context(|| format!("artifact {name} not in manifest (dims compiled: {:?})", self.dims))?;
        Ok(self.dir.join(f))
    }

    /// Whether scan/rerank artifacts exist for a vector dim.
    pub fn has_dim(&self, dim: usize) -> bool {
        self.dims.contains(&dim)
    }

    /// Element count of policy parameter `i`.
    pub fn param_len(&self, i: usize) -> usize {
        self.param_shapes[i].1.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_when_present() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.query_batch, 64);
        assert_eq!(m.base_block, 4096);
        assert_eq!(m.n_knobs, crate::variants::N_KNOBS);
        assert_eq!(m.param_shapes.len(), 7);
        assert_eq!(m.init_params.len(), 7);
        for i in 0..7 {
            assert_eq!(m.init_params[i].len(), m.param_len(i), "param {i}");
        }
        assert!(m.has_dim(128));
        assert!(m.artifact_path("grpo_step").unwrap().exists());
        assert!(m.artifact_path("scan_l2_d128").unwrap().exists());
    }
}
