//! Runtime-dispatched SIMD distance kernels (§6.2) — the explicit
//! one-to-one and one-to-many kernels that GLASS and ParlayANN ship and
//! that this repo previously left to LLVM autovectorization.
//!
//! Two layers:
//!
//! * **Per-pair kernels** — [`portable`] holds the 8-wide chunked reference
//!   implementations (reliably autovectorized on any target); on `x86_64`
//!   an AVX2+FMA variant is hand-written with `std::arch` intrinsics. The
//!   implementation pair is selected **once**, at first use, into plain
//!   function pointers (see [`kernels`]) guarded by
//!   `is_x86_feature_detected!` — DESIGN.md §SIMD-Dispatch explains why
//!   function pointers beat per-call feature checks here.
//! * **Batch kernels** — [`l2_sq_batch`]/[`dot_batch`]/[`distance_batch`]
//!   evaluate one query against a gathered id list, interleaving software
//!   prefetch of vector `i + BATCH_LOOKAHEAD` with the arithmetic for
//!   vector `i` (§6.2 "Batch Processing with Adaptive Prefetching"). Batch
//!   results are **bitwise identical** to calling the per-pair kernel in a
//!   loop — consumers may switch freely between the two paths without
//!   changing search results.

use crate::distance::Metric;

/// A selected per-pair distance kernel.
pub type DistFn = fn(&[f32], &[f32]) -> f32;

/// The dispatched kernel set.
pub struct Kernels {
    pub l2_sq: DistFn,
    pub dot: DistFn,
    /// Which implementation was selected (`"avx2+fma"` or `"portable8"`) —
    /// reported by `benches/micro_distance`.
    pub name: &'static str,
}

/// The process-wide kernel set, selected once on first call (thread-safe;
/// later calls are a single atomic load).
pub fn kernels() -> &'static Kernels {
    static KERNELS: std::sync::OnceLock<Kernels> = std::sync::OnceLock::new();
    KERNELS.get_or_init(select)
}

fn select() -> Kernels {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Kernels {
                l2_sq: avx2::l2_sq,
                dot: avx2::dot,
                name: "avx2+fma",
            };
        }
    }
    Kernels {
        l2_sq: portable::l2_sq,
        dot: portable::dot,
        name: "portable8",
    }
}

/// Portable 8-wide chunked kernels — the reference implementation on every
/// target and the correctness oracle for the property tests.
pub mod portable {
    /// Squared L2 distance, 8-wide chunked for auto-vectorization.
    #[inline]
    pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0f32; 8];
        let chunks = a.len() / 8;
        for c in 0..chunks {
            let ao = &a[c * 8..c * 8 + 8];
            let bo = &b[c * 8..c * 8 + 8];
            for i in 0..8 {
                let d = ao[i] - bo[i];
                acc[i] += d * d;
            }
        }
        let mut sum = acc.iter().sum::<f32>();
        for i in chunks * 8..a.len() {
            let d = a[i] - b[i];
            sum += d * d;
        }
        sum
    }

    /// Inner product, 8-wide chunked.
    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0f32; 8];
        let chunks = a.len() / 8;
        for c in 0..chunks {
            let ao = &a[c * 8..c * 8 + 8];
            let bo = &b[c * 8..c * 8 + 8];
            for i in 0..8 {
                acc[i] += ao[i] * bo[i];
            }
        }
        let mut sum = acc.iter().sum::<f32>();
        for i in chunks * 8..a.len() {
            sum += a[i] * b[i];
        }
        sum
    }
}

/// AVX2+FMA kernels. The safe wrappers are only ever installed into the
/// dispatch table after `is_x86_feature_detected!` confirms both features,
/// which is what makes the `unsafe` inner calls sound.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
        // Hard assert: the impls read through raw pointers, so a length
        // mismatch would be an out-of-bounds read, not a panic like the
        // portable kernel's slice indexing. Negligible next to the kernel.
        assert_eq!(a.len(), b.len());
        // SAFETY: `select` gates this path on runtime AVX2+FMA detection,
        // and the lengths are checked above.
        unsafe { l2_sq_impl(a, b) }
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len());
        // SAFETY: `select` gates this path on runtime AVX2+FMA detection,
        // and the lengths are checked above.
        unsafe { dot_impl(a, b) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn l2_sq_impl(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        // Two accumulators hide FMA latency (ports saturate at ~2 chains).
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 16 <= n {
            let d0 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            let d1 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i + 8)), _mm256_loadu_ps(pb.add(i + 8)));
            acc0 = _mm256_fmadd_ps(d0, d0, acc0);
            acc1 = _mm256_fmadd_ps(d1, d1, acc1);
            i += 16;
        }
        if i + 8 <= n {
            let d = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            acc0 = _mm256_fmadd_ps(d, d, acc0);
            i += 8;
        }
        let mut sum = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            let d = a[i] - b[i];
            sum += d * d;
            i += 1;
        }
        sum
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot_impl(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 8)),
                _mm256_loadu_ps(pb.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        if i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            i += 8;
        }
        let mut sum = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            sum += a[i] * b[i];
            i += 1;
        }
        sum
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }
}

/// Default prefetch lookahead for the batch kernels: while pair `i` is
/// evaluated, the vector of pair `i + lookahead` is pulled toward L1.
/// Sized so the prefetch completes (~100ns DRAM) within a few kernel
/// evaluations without thrashing L1 on short batches. Knob-driven callers
/// (HNSW edge batching, GLASS rerank) pass their own via
/// [`distance_batch_with`].
pub const BATCH_LOOKAHEAD: usize = 4;

/// Default prefetch locality for the batch kernels (3 = `_MM_HINT_T0`).
pub const BATCH_LOCALITY: i32 = 3;

#[inline]
fn vec_at(data: &[f32], dim: usize, id: u32) -> &[f32] {
    let i = id as usize * dim;
    &data[i..i + dim]
}

/// One-to-many kernel core: distances from `q` to each `ids[i]` row of
/// `data`, prefetch pipelined (`lookahead == 0` disables prefetch, same
/// convention as the `prefetch_depth` knob). Clears and refills `out`
/// (index-aligned with `ids`).
#[inline]
fn batch(
    kern: DistFn,
    q: &[f32],
    ids: &[u32],
    data: &[f32],
    dim: usize,
    lookahead: usize,
    locality: i32,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(ids.len());
    if lookahead > 0 {
        for &id in ids.iter().take(lookahead) {
            crate::distance::prefetch(vec_at(data, dim, id), locality);
        }
    }
    for (i, &id) in ids.iter().enumerate() {
        if lookahead > 0 {
            if let Some(&ahead) = ids.get(i + lookahead) {
                crate::distance::prefetch(vec_at(data, dim, ahead), locality);
            }
        }
        out.push(kern(q, vec_at(data, dim, id)));
    }
}

/// Squared-L2 distances from `q` to the `ids` rows of `data` (row-major,
/// `dim` columns), default prefetch schedule. Results land in `out`,
/// index-aligned with `ids`.
#[inline]
pub fn l2_sq_batch(q: &[f32], ids: &[u32], data: &[f32], dim: usize, out: &mut Vec<f32>) {
    batch(kernels().l2_sq, q, ids, data, dim, BATCH_LOOKAHEAD, BATCH_LOCALITY, out);
}

/// Inner products of `q` with the `ids` rows of `data`, default prefetch
/// schedule.
#[inline]
pub fn dot_batch(q: &[f32], ids: &[u32], data: &[f32], dim: usize, out: &mut Vec<f32>) {
    batch(kernels().dot, q, ids, data, dim, BATCH_LOOKAHEAD, BATCH_LOCALITY, out);
}

/// Metric-aware batch distances with the default prefetch schedule. See
/// [`distance_batch_with`].
pub fn distance_batch(
    metric: Metric,
    q: &[f32],
    ids: &[u32],
    data: &[f32],
    dim: usize,
    out: &mut Vec<f32>,
) {
    distance_batch_with(metric, q, ids, data, dim, BATCH_LOOKAHEAD, BATCH_LOCALITY, out);
}

/// Metric-aware batch distances (same convention as [`Metric::distance`]):
/// `L2` → squared L2, `Angular` → `1 - <q,b>`, `Ip` → `-<q,b>`. Bitwise
/// identical to the per-pair path for every `lookahead`/`locality` — the
/// prefetch schedule is a pure speed dial, which is what lets the §6
/// prefetch knobs (`prefetch_depth`, `prefetch_locality`, `lookahead`)
/// keep their runtime meaning on the batched paths.
#[allow(clippy::too_many_arguments)]
pub fn distance_batch_with(
    metric: Metric,
    q: &[f32],
    ids: &[u32],
    data: &[f32],
    dim: usize,
    lookahead: usize,
    locality: i32,
    out: &mut Vec<f32>,
) {
    match metric {
        Metric::L2 => batch(kernels().l2_sq, q, ids, data, dim, lookahead, locality, out),
        Metric::Angular => {
            batch(kernels().dot, q, ids, data, dim, lookahead, locality, out);
            for d in out.iter_mut() {
                *d = 1.0 - *d;
            }
        }
        Metric::Ip => {
            batch(kernels().dot, q, ids, data, dim, lookahead, locality, out);
            for d in out.iter_mut() {
                *d = -*d;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const DIMS: [usize; 10] = [1, 7, 8, 15, 25, 100, 128, 200, 784, 960];

    #[test]
    fn dispatch_selects_a_kernel() {
        let k = kernels();
        assert!(k.name == "avx2+fma" || k.name == "portable8");
        // Selection is stable across calls.
        assert_eq!(kernels().name, k.name);
    }

    #[test]
    fn dispatched_matches_portable_within_tolerance() {
        let mut rng = Rng::new(0x51D);
        for dim in DIMS {
            let a: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
            let b: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
            let (got, want) = ((kernels().l2_sq)(&a, &b), portable::l2_sq(&a, &b));
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "l2_sq dim={dim}: {got} vs {want}"
            );
            let (got, want) = ((kernels().dot)(&a, &b), portable::dot(&a, &b));
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "dot dim={dim}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn batch_is_bitwise_identical_to_per_pair() {
        let mut rng = Rng::new(0xBA7C);
        for dim in [1usize, 7, 25, 128] {
            let n = 100;
            let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
            let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
            // A non-contiguous, repeated id pattern.
            let ids: Vec<u32> = (0..n as u32).rev().step_by(3).chain([0, 0]).collect();
            let mut out = Vec::new();
            l2_sq_batch(&q, &ids, &data, dim, &mut out);
            assert_eq!(out.len(), ids.len());
            for (&id, &d) in ids.iter().zip(&out) {
                assert_eq!(d, (kernels().l2_sq)(&q, vec_at(&data, dim, id)), "dim={dim}");
            }
            dot_batch(&q, &ids, &data, dim, &mut out);
            for (&id, &d) in ids.iter().zip(&out) {
                assert_eq!(d, (kernels().dot)(&q, vec_at(&data, dim, id)), "dim={dim}");
            }
        }
    }

    #[test]
    fn metric_batch_matches_metric_distance() {
        let mut rng = Rng::new(0x3E7);
        let dim = 33;
        let n = 64;
        let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
        let ids: Vec<u32> = (0..n as u32).collect();
        let mut out = Vec::new();
        for metric in [Metric::L2, Metric::Angular, Metric::Ip] {
            distance_batch(metric, &q, &ids, &data, dim, &mut out);
            for (&id, &d) in ids.iter().zip(&out) {
                assert_eq!(d, metric.distance(&q, vec_at(&data, dim, id)), "{metric:?}");
            }
        }
    }

    #[test]
    fn prefetch_schedule_is_result_invariant() {
        // lookahead/locality only prefetch — outputs must be bitwise
        // identical for every schedule (including disabled).
        let mut rng = Rng::new(0xFE7C);
        let dim = 96;
        let n = 80;
        let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
        let ids: Vec<u32> = (0..n as u32).collect();
        let mut want = Vec::new();
        distance_batch_with(Metric::L2, &q, &ids, &data, dim, 0, 3, &mut want);
        for (lookahead, locality) in [(1usize, 1i32), (4, 3), (16, 0), (100, 2)] {
            let mut got = Vec::new();
            distance_batch_with(Metric::L2, &q, &ids, &data, dim, lookahead, locality, &mut got);
            assert_eq!(got, want, "lookahead={lookahead} locality={locality}");
        }
    }

    #[test]
    fn empty_ids_and_empty_vectors() {
        let mut out = vec![1.0f32; 4];
        l2_sq_batch(&[1.0], &[], &[0.0, 2.0], 1, &mut out);
        assert!(out.is_empty());
        // Zero-length vectors: distance 0 / dot 0.
        assert_eq!((kernels().l2_sq)(&[], &[]), 0.0);
        assert_eq!((kernels().dot)(&[], &[]), 0.0);
    }
}
