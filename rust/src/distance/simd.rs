//! Runtime-dispatched SIMD distance kernels (§6.2) — the explicit
//! one-to-one and one-to-many kernels that GLASS and ParlayANN ship and
//! that this repo previously left to LLVM autovectorization.
//!
//! Two layers:
//!
//! * **Per-pair kernels** — [`portable`] holds the 8-wide chunked reference
//!   implementations (reliably autovectorized on any target); on `x86_64`
//!   an AVX2+FMA variant is hand-written with `std::arch` intrinsics. The
//!   implementation pair is selected **once**, at first use, into plain
//!   function pointers (see [`kernels`]) guarded by
//!   `is_x86_feature_detected!` — DESIGN.md §SIMD-Dispatch explains why
//!   function pointers beat per-call feature checks here.
//! * **Batch kernels** — [`l2_sq_batch`]/[`dot_batch`]/[`distance_batch`]
//!   evaluate one query against a gathered id list, interleaving software
//!   prefetch of vector `i + BATCH_LOOKAHEAD` with the arithmetic for
//!   vector `i` (§6.2 "Batch Processing with Adaptive Prefetching"). Batch
//!   results are **bitwise identical** to calling the per-pair kernel in a
//!   loop — consumers may switch freely between the two paths without
//!   changing search results.
//!
//! The same two layers exist for the **int8 SQ8 codes** that drive GLASS's
//! quantized preliminary search (§2.3) and the IVF posting-list scan:
//! [`portable_i8`] keeps the 32-wide i16-difference scalar loops (the
//! `pmaddwd`-shaped forms the vectorizer likes — EXPERIMENTS.md §Perf/L3)
//! as the fallback and correctness oracle, [`kernels_i8`] dispatches to
//! hand-written AVX2 kernels (`_mm256_cvtepi8_epi16` widening +
//! `_mm256_madd_epi16` accumulation), and
//! [`l2_sq_i8_batch`]/[`dot_i8_batch`]/[`quant_distance_batch`] are the
//! one-to-many forms. Because every i8 kernel accumulates in i32, SIMD,
//! portable, and batch results are **exactly equal** (integer arithmetic is
//! associative) — not merely within tolerance like the f32 kernels.

use crate::distance::Metric;

/// A selected per-pair distance kernel.
pub type DistFn = fn(&[f32], &[f32]) -> f32;

/// The dispatched kernel set.
pub struct Kernels {
    pub l2_sq: DistFn,
    pub dot: DistFn,
    /// Which implementation was selected (`"avx2+fma"` or `"portable8"`) —
    /// reported by `benches/micro_distance`.
    pub name: &'static str,
}

/// The process-wide kernel set, selected once on first call (thread-safe;
/// later calls are a single atomic load).
pub fn kernels() -> &'static Kernels {
    static KERNELS: std::sync::OnceLock<Kernels> = std::sync::OnceLock::new();
    KERNELS.get_or_init(select)
}

fn select() -> Kernels {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Kernels {
                l2_sq: avx2::l2_sq,
                dot: avx2::dot,
                name: "avx2+fma",
            };
        }
    }
    Kernels {
        l2_sq: portable::l2_sq,
        dot: portable::dot,
        name: "portable8",
    }
}

/// Portable 8-wide chunked kernels — the reference implementation on every
/// target and the correctness oracle for the property tests.
pub mod portable {
    /// Squared L2 distance, 8-wide chunked for auto-vectorization.
    #[inline]
    pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0f32; 8];
        let chunks = a.len() / 8;
        for c in 0..chunks {
            let ao = &a[c * 8..c * 8 + 8];
            let bo = &b[c * 8..c * 8 + 8];
            for i in 0..8 {
                let d = ao[i] - bo[i];
                acc[i] += d * d;
            }
        }
        let mut sum = acc.iter().sum::<f32>();
        for i in chunks * 8..a.len() {
            let d = a[i] - b[i];
            sum += d * d;
        }
        sum
    }

    /// Inner product, 8-wide chunked.
    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0f32; 8];
        let chunks = a.len() / 8;
        for c in 0..chunks {
            let ao = &a[c * 8..c * 8 + 8];
            let bo = &b[c * 8..c * 8 + 8];
            for i in 0..8 {
                acc[i] += ao[i] * bo[i];
            }
        }
        let mut sum = acc.iter().sum::<f32>();
        for i in chunks * 8..a.len() {
            sum += a[i] * b[i];
        }
        sum
    }
}

/// AVX2+FMA kernels. The safe wrappers are only ever installed into the
/// dispatch table after `is_x86_feature_detected!` confirms both features,
/// which is what makes the `unsafe` inner calls sound.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
        // Hard assert: the impls read through raw pointers, so a length
        // mismatch would be an out-of-bounds read, not a panic like the
        // portable kernel's slice indexing. Negligible next to the kernel.
        assert_eq!(a.len(), b.len());
        // SAFETY: `select` gates this path on runtime AVX2+FMA detection,
        // and the lengths are checked above.
        unsafe { l2_sq_impl(a, b) }
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len());
        // SAFETY: `select` gates this path on runtime AVX2+FMA detection,
        // and the lengths are checked above.
        unsafe { dot_impl(a, b) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn l2_sq_impl(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        // Two accumulators hide FMA latency (ports saturate at ~2 chains).
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 16 <= n {
            let d0 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            let d1 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i + 8)), _mm256_loadu_ps(pb.add(i + 8)));
            acc0 = _mm256_fmadd_ps(d0, d0, acc0);
            acc1 = _mm256_fmadd_ps(d1, d1, acc1);
            i += 16;
        }
        if i + 8 <= n {
            let d = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            acc0 = _mm256_fmadd_ps(d, d, acc0);
            i += 8;
        }
        let mut sum = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            let d = a[i] - b[i];
            sum += d * d;
            i += 1;
        }
        sum
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot_impl(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 8)),
                _mm256_loadu_ps(pb.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        if i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            i += 8;
        }
        let mut sum = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            sum += a[i] * b[i];
            i += 1;
        }
        sum
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }
}

/// A selected per-pair int8 distance kernel (i32 accumulation — exact).
pub type DistFnI8 = fn(&[i8], &[i8]) -> i32;

/// The dispatched int8 kernel set.
pub struct KernelsI8 {
    pub l2_sq: DistFnI8,
    pub dot: DistFnI8,
    /// Which implementation was selected (`"avx2"` or `"portable32"`) —
    /// reported by `benches/micro_distance`.
    pub name: &'static str,
}

/// The process-wide int8 kernel set, selected once on first call. Unlike
/// the f32 set this only needs AVX2 (the arithmetic is `pmaddwd`, no FMA).
pub fn kernels_i8() -> &'static KernelsI8 {
    static KERNELS: std::sync::OnceLock<KernelsI8> = std::sync::OnceLock::new();
    KERNELS.get_or_init(select_i8)
}

fn select_i8() -> KernelsI8 {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return KernelsI8 {
                l2_sq: avx2_i8::l2_sq,
                dot: avx2_i8::dot,
                name: "avx2",
            };
        }
    }
    KernelsI8 {
        l2_sq: portable_i8::l2_sq,
        dot: portable_i8::dot,
        name: "portable32",
    }
}

/// Portable 32-wide chunked int8 kernels — the reference implementation on
/// every target and the exact-equality oracle for the i8 property tests.
/// i32 accumulation bounds exactness: safe for `dim * 254^2 < 2^31`, i.e.
/// any dim below ~33k (Table 2 tops out at 960).
pub mod portable_i8 {
    /// i8 squared-L2 accumulated in i32.
    ///
    /// §Perf: 32-wide chunks with an i16 difference (`pmaddwd`-shaped for
    /// the vectorizer) measured 1.7x faster than the naive 16-wide i32 form
    /// with `target-cpu=native` (EXPERIMENTS.md §Perf/L3: 18.1 → 10.4
    /// ns/pair at d=128 on this box).
    #[inline]
    pub fn l2_sq(a: &[i8], b: &[i8]) -> i32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0i32; 32];
        let chunks = a.len() / 32;
        for c in 0..chunks {
            let ao = &a[c * 32..c * 32 + 32];
            let bo = &b[c * 32..c * 32 + 32];
            for i in 0..32 {
                let d = (ao[i] as i16 - bo[i] as i16) as i32;
                acc[i] += d * d;
            }
        }
        let mut sum: i32 = acc.iter().sum();
        for i in chunks * 32..a.len() {
            let d = a[i] as i32 - b[i] as i32;
            sum += d * d;
        }
        sum
    }

    /// i8 inner product accumulated in i32 (same `pmaddwd`-shaped pattern —
    /// 2.3x over the naive form, see §Perf).
    #[inline]
    pub fn dot(a: &[i8], b: &[i8]) -> i32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0i32; 32];
        let chunks = a.len() / 32;
        for c in 0..chunks {
            let ao = &a[c * 32..c * 32 + 32];
            let bo = &b[c * 32..c * 32 + 32];
            for i in 0..32 {
                acc[i] += (ao[i] as i16 as i32) * (bo[i] as i16 as i32);
            }
        }
        let mut sum: i32 = acc.iter().sum();
        for i in chunks * 32..a.len() {
            sum += a[i] as i32 * b[i] as i32;
        }
        sum
    }
}

/// AVX2 int8 kernels: widen 16 codes at a time to i16 lanes
/// (`_mm256_cvtepi8_epi16`), then `_mm256_madd_epi16` folds pairwise
/// i16×i16 products into i32 lanes — the literal `pmaddwd` the portable
/// form is shaped after. i32 lane accumulation means the result is the
/// same integer the scalar loop computes, in any lane order.
#[cfg(target_arch = "x86_64")]
mod avx2_i8 {
    use std::arch::x86_64::*;

    pub fn l2_sq(a: &[i8], b: &[i8]) -> i32 {
        // Hard assert: the impls read through raw pointers (see the f32
        // kernels for the rationale).
        assert_eq!(a.len(), b.len());
        // SAFETY: `select_i8` gates this path on runtime AVX2 detection,
        // and the lengths are checked above.
        unsafe { l2_sq_impl(a, b) }
    }

    pub fn dot(a: &[i8], b: &[i8]) -> i32 {
        assert_eq!(a.len(), b.len());
        // SAFETY: as above.
        unsafe { dot_impl(a, b) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn l2_sq_impl(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        // Two accumulator chains over 16-code halves of a 32-code step.
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let mut i = 0;
        while i + 32 <= n {
            let d0 = _mm256_sub_epi16(load_epi8_as_epi16(pa.add(i)), load_epi8_as_epi16(pb.add(i)));
            let d1 = _mm256_sub_epi16(
                load_epi8_as_epi16(pa.add(i + 16)),
                load_epi8_as_epi16(pb.add(i + 16)),
            );
            acc0 = _mm256_add_epi32(acc0, _mm256_madd_epi16(d0, d0));
            acc1 = _mm256_add_epi32(acc1, _mm256_madd_epi16(d1, d1));
            i += 32;
        }
        if i + 16 <= n {
            let d = _mm256_sub_epi16(load_epi8_as_epi16(pa.add(i)), load_epi8_as_epi16(pb.add(i)));
            acc0 = _mm256_add_epi32(acc0, _mm256_madd_epi16(d, d));
            i += 16;
        }
        let mut sum = hsum_epi32(_mm256_add_epi32(acc0, acc1));
        while i < n {
            let d = a[i] as i32 - b[i] as i32;
            sum += d * d;
            i += 1;
        }
        sum
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dot_impl(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let mut i = 0;
        while i + 32 <= n {
            acc0 = _mm256_add_epi32(
                acc0,
                _mm256_madd_epi16(load_epi8_as_epi16(pa.add(i)), load_epi8_as_epi16(pb.add(i))),
            );
            acc1 = _mm256_add_epi32(
                acc1,
                _mm256_madd_epi16(
                    load_epi8_as_epi16(pa.add(i + 16)),
                    load_epi8_as_epi16(pb.add(i + 16)),
                ),
            );
            i += 32;
        }
        if i + 16 <= n {
            acc0 = _mm256_add_epi32(
                acc0,
                _mm256_madd_epi16(load_epi8_as_epi16(pa.add(i)), load_epi8_as_epi16(pb.add(i))),
            );
            i += 16;
        }
        let mut sum = hsum_epi32(_mm256_add_epi32(acc0, acc1));
        while i < n {
            sum += a[i] as i32 * b[i] as i32;
            i += 1;
        }
        sum
    }

    /// Load 16 i8 codes and sign-extend to 16 i16 lanes.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn load_epi8_as_epi16(p: *const i8) -> __m256i {
        _mm256_cvtepi8_epi16(_mm_loadu_si128(p as *const __m128i))
    }

    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi32(v: __m256i) -> i32 {
        let lo = _mm256_castsi256_si128(v);
        let hi = _mm256_extracti128_si256(v, 1);
        let s = _mm_add_epi32(lo, hi);
        let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b01));
        _mm_cvtsi128_si32(s)
    }
}

/// Default prefetch lookahead for the batch kernels: while pair `i` is
/// evaluated, the vector of pair `i + lookahead` is pulled toward L1.
/// Sized so the prefetch completes (~100ns DRAM) within a few kernel
/// evaluations without thrashing L1 on short batches. Knob-driven callers
/// (HNSW edge batching, GLASS rerank) pass their own via
/// [`distance_batch_with`].
pub const BATCH_LOOKAHEAD: usize = 4;

/// Default prefetch locality for the batch kernels (3 = `_MM_HINT_T0`).
pub const BATCH_LOCALITY: i32 = 3;

/// Row `id` of a row-major `[n, dim]` matrix of any element type.
#[inline]
fn row_at<E>(data: &[E], dim: usize, id: u32) -> &[E] {
    let i = id as usize * dim;
    &data[i..i + dim]
}

/// Test-only f32 alias of [`row_at`] (the batch paths call `row_at`
/// directly through [`batch_core`]).
#[cfg(test)]
#[inline]
fn vec_at(data: &[f32], dim: usize, id: u32) -> &[f32] {
    row_at(data, dim, id)
}

/// One-to-many kernel core shared by the f32 and i8 paths: evaluate `q`
/// against each `ids[i]` row of `data`, prefetch pipelined (`lookahead ==
/// 0` disables prefetch, same convention as the `prefetch_depth` knob) —
/// warm the first `lookahead` rows, then hint row `i + lookahead` (typeless
/// byte-pointer prefetch) while evaluating row `i`. Clears and refills
/// `out` (index-aligned with `ids`). ONE implementation of the schedule so
/// a fix to the pipeline can never drift between element types.
#[allow(clippy::too_many_arguments)]
#[inline]
fn batch_core<E, T>(
    q: &[E],
    ids: &[u32],
    data: &[E],
    dim: usize,
    lookahead: usize,
    locality: i32,
    out: &mut Vec<T>,
    eval: impl Fn(&[E], &[E]) -> T,
) {
    out.clear();
    out.reserve(ids.len());
    if lookahead > 0 {
        for &id in ids.iter().take(lookahead) {
            crate::distance::prefetch_ptr(row_at(data, dim, id).as_ptr().cast(), locality);
        }
    }
    for (i, &id) in ids.iter().enumerate() {
        if lookahead > 0 {
            if let Some(&ahead) = ids.get(i + lookahead) {
                crate::distance::prefetch_ptr(row_at(data, dim, ahead).as_ptr().cast(), locality);
            }
        }
        out.push(eval(q, row_at(data, dim, id)));
    }
}

/// f32 instantiation of [`batch_core`] (kept as the narrow internal entry
/// point the public f32 batch API calls).
#[allow(clippy::too_many_arguments)]
#[inline]
fn batch(
    kern: DistFn,
    q: &[f32],
    ids: &[u32],
    data: &[f32],
    dim: usize,
    lookahead: usize,
    locality: i32,
    out: &mut Vec<f32>,
) {
    batch_core(q, ids, data, dim, lookahead, locality, out, kern);
}

/// Squared-L2 distances from `q` to the `ids` rows of `data` (row-major,
/// `dim` columns), default prefetch schedule. Results land in `out`,
/// index-aligned with `ids`.
#[inline]
pub fn l2_sq_batch(q: &[f32], ids: &[u32], data: &[f32], dim: usize, out: &mut Vec<f32>) {
    batch(kernels().l2_sq, q, ids, data, dim, BATCH_LOOKAHEAD, BATCH_LOCALITY, out);
}

/// Inner products of `q` with the `ids` rows of `data`, default prefetch
/// schedule.
#[inline]
pub fn dot_batch(q: &[f32], ids: &[u32], data: &[f32], dim: usize, out: &mut Vec<f32>) {
    batch(kernels().dot, q, ids, data, dim, BATCH_LOOKAHEAD, BATCH_LOCALITY, out);
}

/// Metric-aware batch distances with the default prefetch schedule. See
/// [`distance_batch_with`].
pub fn distance_batch(
    metric: Metric,
    q: &[f32],
    ids: &[u32],
    data: &[f32],
    dim: usize,
    out: &mut Vec<f32>,
) {
    distance_batch_with(metric, q, ids, data, dim, BATCH_LOOKAHEAD, BATCH_LOCALITY, out);
}

/// Metric-aware batch distances (same convention as [`Metric::distance`]):
/// `L2` → squared L2, `Angular` → `1 - <q,b>`, `Ip` → `-<q,b>`. Bitwise
/// identical to the per-pair path for every `lookahead`/`locality` — the
/// prefetch schedule is a pure speed dial, which is what lets the §6
/// prefetch knobs (`prefetch_depth`, `prefetch_locality`, `lookahead`)
/// keep their runtime meaning on the batched paths.
#[allow(clippy::too_many_arguments)]
pub fn distance_batch_with(
    metric: Metric,
    q: &[f32],
    ids: &[u32],
    data: &[f32],
    dim: usize,
    lookahead: usize,
    locality: i32,
    out: &mut Vec<f32>,
) {
    match metric {
        Metric::L2 => batch(kernels().l2_sq, q, ids, data, dim, lookahead, locality, out),
        Metric::Angular => {
            batch(kernels().dot, q, ids, data, dim, lookahead, locality, out);
            for d in out.iter_mut() {
                *d = 1.0 - *d;
            }
        }
        Metric::Ip => {
            batch(kernels().dot, q, ids, data, dim, lookahead, locality, out);
            for d in out.iter_mut() {
                *d = -*d;
            }
        }
    }
}

/// Test-only i8 alias of [`row_at`].
#[cfg(test)]
#[inline]
fn code_at(codes: &[i8], dim: usize, id: u32) -> &[i8] {
    row_at(codes, dim, id)
}

/// int8 instantiation of [`batch_core`]: raw i32 distances, each mapped
/// through `map` into `out` (identity for the raw batch API, the `scale²`
/// metric mapping for [`quant_distance_batch_with`]).
#[allow(clippy::too_many_arguments)]
#[inline]
fn batch_i8<T>(
    kern: DistFnI8,
    q: &[i8],
    ids: &[u32],
    codes: &[i8],
    dim: usize,
    lookahead: usize,
    locality: i32,
    out: &mut Vec<T>,
    map: impl Fn(i32) -> T,
) {
    batch_core(q, ids, codes, dim, lookahead, locality, out, |a, b| map(kern(a, b)));
}

/// Raw i8 squared-L2 distances from `q` to the `ids` rows of `codes`
/// (row-major, `dim` columns), default prefetch schedule. Exactly equal to
/// per-pair [`crate::distance::quant::l2_sq_i8`] calls.
#[inline]
pub fn l2_sq_i8_batch(q: &[i8], ids: &[u32], codes: &[i8], dim: usize, out: &mut Vec<i32>) {
    batch_i8(kernels_i8().l2_sq, q, ids, codes, dim, BATCH_LOOKAHEAD, BATCH_LOCALITY, out, |r| r);
}

/// Raw i8 inner products of `q` with the `ids` rows of `codes`, default
/// prefetch schedule. Exactly equal to per-pair
/// [`crate::distance::quant::dot_i8`] calls.
#[inline]
pub fn dot_i8_batch(q: &[i8], ids: &[u32], codes: &[i8], dim: usize, out: &mut Vec<i32>) {
    batch_i8(kernels_i8().dot, q, ids, codes, dim, BATCH_LOOKAHEAD, BATCH_LOCALITY, out, |r| r);
}

/// Metric-aware SQ8 batch distances with the default prefetch schedule.
/// See [`quant_distance_batch_with`].
#[allow(clippy::too_many_arguments)]
pub fn quant_distance_batch(
    metric: Metric,
    q: &[i8],
    ids: &[u32],
    codes: &[i8],
    dim: usize,
    scale: f32,
    out: &mut Vec<f32>,
) {
    quant_distance_batch_with(
        metric,
        q,
        ids,
        codes,
        dim,
        scale,
        BATCH_LOOKAHEAD,
        BATCH_LOCALITY,
        out,
    );
}

/// Metric-aware SQ8 batch distances in f32 metric units (same convention as
/// [`crate::distance::quant::QuantizedStore::distance`]). The integer
/// kernel runs per pair and the `scale²` factor is computed once per batch;
/// because the raw distance is an exact i32 and the final mapping is the
/// same one the per-pair path applies, results are **bitwise identical** to
/// per-pair `QuantizedStore::distance` calls for every
/// `lookahead`/`locality` — the quantized knob stays a pure speed dial.
#[allow(clippy::too_many_arguments)]
pub fn quant_distance_batch_with(
    metric: Metric,
    q: &[i8],
    ids: &[u32],
    codes: &[i8],
    dim: usize,
    scale: f32,
    lookahead: usize,
    locality: i32,
    out: &mut Vec<f32>,
) {
    let s2 = scale * scale;
    let kern = match metric {
        Metric::L2 => kernels_i8().l2_sq,
        Metric::Angular | Metric::Ip => kernels_i8().dot,
    };
    batch_i8(kern, q, ids, codes, dim, lookahead, locality, out, |raw| {
        crate::distance::quant::map_quant_raw(metric, raw, s2)
    });
}

// ---------------------------------------------------------------------------
// 4-bit PQ ADC fast-scan (DESIGN.md §PQ-Fast-Scan).
//
// Asymmetric distance computation for product-quantized rows: a query is
// turned into per-subspace lookup tables once ([`PqLut`]), then the distance
// to a stored row is the sum of one table entry per 4-bit code. The tables
// are quantized to u8 with one per-query scale/bias so the accumulation is
// pure integer arithmetic — like the i8 kernels, SIMD, portable, and batch
// forms are **exactly** equal, and the f32 mapping back to metric units is
// one shared multiply-add.
// ---------------------------------------------------------------------------

/// Rows per fast-scan block: the AVX2 kernel scans 32 packed code rows per
/// iteration (one `_mm256_shuffle_epi8` table gather per nibble position),
/// so block storage interleaves codes *position-major* in groups of 32 rows:
/// byte `p` of rows `0..32`, then byte `p+1` of rows `0..32`, …
pub const PQ_BLOCK: usize = 32;

/// A query's quantized ADC lookup tables: `mp × 16` u8 entries plus the
/// per-query scale (`delta`) and bias that map an integer accumulator back
/// to f32 metric units.
///
/// Quantization: per subspace `j`, the f32 table minimum `b_j` is
/// subtracted; one global step `delta = max_j(spread_j) / 255` quantizes
/// every entry to `round((t - b_j) / delta)` (clamped to 255). Each entry
/// rounds within `delta / 2`, so the reconstructed distance
/// `sum * delta + Σb_j` errs by at most `m · delta / 2` — the u8 bound
/// DESIGN.md §PQ-Fast-Scan documents. Approximate distances only ever rank
/// candidates; survivors are re-ranked in exact f32.
#[derive(Clone, Debug)]
pub struct PqLut {
    /// `mp × 16` u8 tables, subspace-major (`tables[j * 16 + c]`). When `m`
    /// is odd, a phantom all-zero table pads `mp` to even so every packed
    /// byte has both a low-nibble and a high-nibble table.
    tables: Vec<u8>,
    /// Padded subspace count (`m` rounded up to even).
    mp: usize,
    /// f32 value of one accumulator count (`0.0` for degenerate tables).
    delta: f32,
    /// Sum of per-subspace table minima plus the metric constant.
    bias: f32,
}

impl PqLut {
    /// Quantize per-subspace f32 distance tables (`m × 16`, subspace-major,
    /// smaller = closer) into u8 with one per-query scale/bias.
    /// `metric_bias` is the metric's additive constant (`1.0` for Angular's
    /// `1 - <q,b>`, `0.0` otherwise), folded into the bias so
    /// [`PqLut::decode`] lands directly in metric units.
    pub fn quantize(raw: &[f32], m: usize, metric_bias: f32) -> PqLut {
        assert!(
            (1..=256).contains(&m),
            "pq subquantizer count {m} out of range [1, 256]"
        );
        assert_eq!(raw.len(), m * 16, "pq raw table shape mismatch");
        let mp = m + (m & 1);
        // f64 bias accumulation: one rounding at the end keeps the bias
        // independent of subspace count.
        let mut bias = metric_bias as f64;
        let mut spread = 0f32;
        let mut mins = [0f32; 256];
        for j in 0..m {
            let t = &raw[j * 16..j * 16 + 16];
            let lo = t.iter().copied().fold(f32::INFINITY, f32::min);
            let hi = t.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            mins[j] = lo;
            bias += lo as f64;
            spread = spread.max(hi - lo);
        }
        let (delta, inv) = if spread > 0.0 {
            (spread / 255.0, 255.0 / spread)
        } else {
            (0.0, 0.0)
        };
        let mut tables = vec![0u8; mp * 16];
        for j in 0..m {
            for c in 0..16 {
                let q = ((raw[j * 16 + c] - mins[j]) * inv).round();
                tables[j * 16 + c] = q.clamp(0.0, 255.0) as u8;
            }
        }
        PqLut { tables, mp, delta, bias: bias as f32 }
    }

    /// The raw `mp × 16` u8 tables (subspace-major).
    #[inline]
    pub fn tables(&self) -> &[u8] {
        &self.tables
    }

    /// Packed bytes per code row this LUT scans (`mp / 2` — equals the
    /// store's `(m + 1) / 2` row stride for every `m`).
    #[inline]
    pub fn row_bytes(&self) -> usize {
        self.mp / 2
    }

    /// Map an integer ADC accumulator to f32 metric units. ONE multiply-add
    /// shared by the per-pair, block, and batch paths — which is what makes
    /// them bitwise identical.
    #[inline]
    pub fn decode(&self, sum: u32) -> f32 {
        sum as f32 * self.delta + self.bias
    }
}

/// Portable scalar ADC kernels — the per-pair form and the exact-equality
/// oracle for the AVX2 block kernel.
pub mod portable_pq {
    use super::{PqLut, PQ_BLOCK};

    /// ADC over one packed row: one table lookup per nibble, u32 sum. This
    /// IS the per-pair kernel on every target — a single row has exactly
    /// one lookup per table, so there is no in-register parallelism to
    /// exploit; the `pshufb` win ([`super::kernels_pq`]) needs 32 rows
    /// against the same tables.
    #[inline]
    pub fn adc(lut: &PqLut, row: &[u8]) -> u32 {
        debug_assert_eq!(row.len(), lut.row_bytes());
        let t = lut.tables();
        let mut sum = 0u32;
        for (p, &b) in row.iter().enumerate() {
            sum += t[p * 32 + (b & 0x0F) as usize] as u32;
            sum += t[p * 32 + 16 + (b >> 4) as usize] as u32;
        }
        sum
    }

    /// Scalar 32-row block scan over the position-major layout — the
    /// portable fallback of [`super::kernels_pq`] and the oracle the AVX2
    /// form must match exactly (asserted by the property tests).
    pub fn adc_block(lut: &PqLut, block: &[u8], out: &mut [u32; PQ_BLOCK]) {
        assert_eq!(block.len(), lut.row_bytes() * PQ_BLOCK);
        let t = lut.tables();
        out.fill(0);
        for p in 0..lut.row_bytes() {
            let col = &block[p * PQ_BLOCK..(p + 1) * PQ_BLOCK];
            let tlo = &t[p * 32..p * 32 + 16];
            let thi = &t[p * 32 + 16..p * 32 + 32];
            for (s, &b) in col.iter().enumerate() {
                out[s] += tlo[(b & 0x0F) as usize] as u32 + thi[(b >> 4) as usize] as u32;
            }
        }
    }
}

/// AVX2 fast-scan block kernel: the FAISS "fast scan" idiom. Both nibble
/// tables of one byte position are broadcast into a ymm register
/// (16 entries per 128-bit lane), and one `_mm256_shuffle_epi8` gathers 32
/// table entries — one per row of the block — in a single instruction.
/// Accumulation is u16 (bounded: `mp ≤ 256` keeps every lane ≤ 65280),
/// widened to the caller's u32 slots at the end; integer arithmetic makes
/// the result exactly the scalar oracle's.
#[cfg(target_arch = "x86_64")]
mod avx2_pq {
    use super::{PqLut, PQ_BLOCK};
    use std::arch::x86_64::*;

    pub fn adc_block(lut: &PqLut, block: &[u8], out: &mut [u32; PQ_BLOCK]) {
        // Hard assert: the impl reads through raw pointers (the tables'
        // length is mp*16 by construction).
        assert_eq!(block.len(), lut.row_bytes() * PQ_BLOCK);
        // SAFETY: `select_pq` gates this path on runtime AVX2 detection,
        // and the lengths are checked above.
        unsafe { adc_block_impl(lut, block, out) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn adc_block_impl(lut: &PqLut, block: &[u8], out: &mut [u32; PQ_BLOCK]) {
        let row_bytes = lut.row_bytes();
        let tables = lut.tables().as_ptr();
        let codes = block.as_ptr();
        let nib = _mm256_set1_epi8(0x0F);
        let zero = _mm256_setzero_si256();
        // Two u16 accumulators: `unpacklo/hi_epi8` are lane-local, so
        // acc_a holds rows {0..8, 16..24} and acc_b rows {8..16, 24..32}.
        let mut acc_a = zero;
        let mut acc_b = zero;
        for p in 0..row_bytes {
            let c = _mm256_loadu_si256(codes.add(p * PQ_BLOCK) as *const __m256i);
            let lo = _mm256_and_si256(c, nib);
            let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(c), nib);
            let tlo = _mm256_broadcastsi128_si256(_mm_loadu_si128(
                tables.add(p * 32) as *const __m128i
            ));
            let thi = _mm256_broadcastsi128_si256(_mm_loadu_si128(
                tables.add(p * 32 + 16) as *const __m128i,
            ));
            let vlo = _mm256_shuffle_epi8(tlo, lo);
            let vhi = _mm256_shuffle_epi8(thi, hi);
            acc_a = _mm256_add_epi16(
                acc_a,
                _mm256_add_epi16(_mm256_unpacklo_epi8(vlo, zero), _mm256_unpacklo_epi8(vhi, zero)),
            );
            acc_b = _mm256_add_epi16(
                acc_b,
                _mm256_add_epi16(_mm256_unpackhi_epi8(vlo, zero), _mm256_unpackhi_epi8(vhi, zero)),
            );
        }
        let mut a = [0u16; 16];
        let mut b = [0u16; 16];
        _mm256_storeu_si256(a.as_mut_ptr() as *mut __m256i, acc_a);
        _mm256_storeu_si256(b.as_mut_ptr() as *mut __m256i, acc_b);
        for i in 0..8 {
            out[i] = a[i] as u32;
            out[8 + i] = b[i] as u32;
            out[16 + i] = a[8 + i] as u32;
            out[24 + i] = b[8 + i] as u32;
        }
    }
}

/// A selected PQ block-scan kernel (`out[s]` = ADC sum of row `s`).
pub type PqBlockFn = fn(&PqLut, &[u8], &mut [u32; PQ_BLOCK]);

/// The dispatched PQ fast-scan kernel set.
pub struct KernelsPq {
    /// 32-row position-major block scan.
    pub block: PqBlockFn,
    /// Which implementation was selected (`"avx2-fastscan"` or
    /// `"portable-fastscan"`) — reported by `benches/micro_distance`.
    pub name: &'static str,
}

/// The process-wide PQ kernel set, selected once on first call (AVX2 only —
/// the arithmetic is `pshufb` + u16 adds, no FMA).
pub fn kernels_pq() -> &'static KernelsPq {
    static KERNELS: std::sync::OnceLock<KernelsPq> = std::sync::OnceLock::new();
    KERNELS.get_or_init(select_pq)
}

fn select_pq() -> KernelsPq {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return KernelsPq {
                block: avx2_pq::adc_block,
                name: "avx2-fastscan",
            };
        }
    }
    KernelsPq {
        block: portable_pq::adc_block,
        name: "portable-fastscan",
    }
}

/// Per-pair ADC over one packed row in integer counts (decode with
/// [`PqLut::decode`]). Scalar on every target — see [`portable_pq::adc`]
/// for why the single-row form has no SIMD variant.
#[inline]
pub fn pq_adc(lut: &PqLut, row: &[u8]) -> u32 {
    portable_pq::adc(lut, row)
}

/// One-to-many ADC distances (f32 metric units) from a query LUT to the
/// `ids` rows of a row-major packed code matrix, default prefetch
/// schedule. Bitwise identical to per-pair `lut.decode(pq_adc(..))` calls.
#[inline]
pub fn pq_adc_batch(lut: &PqLut, ids: &[u32], codes: &[u8], out: &mut Vec<f32>) {
    pq_adc_batch_with(lut, ids, codes, BATCH_LOOKAHEAD, BATCH_LOCALITY, out);
}

/// [`pq_adc_batch`] with an explicit prefetch schedule (`lookahead == 0`
/// disables prefetch; the schedule is a pure speed dial — results are
/// bitwise identical for every schedule, same discipline as the f32/i8
/// batch kernels). Code rows are tiny (`(m+1)/2` bytes), so the prefetch
/// hint covers the whole row of pair `i + lookahead`.
pub fn pq_adc_batch_with(
    lut: &PqLut,
    ids: &[u32],
    codes: &[u8],
    lookahead: usize,
    locality: i32,
    out: &mut Vec<f32>,
) {
    let row_bytes = lut.row_bytes();
    batch_core(&[], ids, codes, row_bytes, lookahead, locality, out, |_q: &[u8], row| {
        lut.decode(portable_pq::adc(lut, row))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const DIMS: [usize; 10] = [1, 7, 8, 15, 25, 100, 128, 200, 784, 960];

    #[test]
    fn dispatch_selects_a_kernel() {
        let k = kernels();
        assert!(k.name == "avx2+fma" || k.name == "portable8");
        // Selection is stable across calls.
        assert_eq!(kernels().name, k.name);
    }

    #[test]
    fn dispatched_matches_portable_within_tolerance() {
        let mut rng = Rng::new(0x51D);
        for dim in DIMS {
            let a: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
            let b: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
            let (got, want) = ((kernels().l2_sq)(&a, &b), portable::l2_sq(&a, &b));
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "l2_sq dim={dim}: {got} vs {want}"
            );
            let (got, want) = ((kernels().dot)(&a, &b), portable::dot(&a, &b));
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "dot dim={dim}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn batch_is_bitwise_identical_to_per_pair() {
        let mut rng = Rng::new(0xBA7C);
        for dim in [1usize, 7, 25, 128] {
            let n = 100;
            let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
            let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
            // A non-contiguous, repeated id pattern.
            let ids: Vec<u32> = (0..n as u32).rev().step_by(3).chain([0, 0]).collect();
            let mut out = Vec::new();
            l2_sq_batch(&q, &ids, &data, dim, &mut out);
            assert_eq!(out.len(), ids.len());
            for (&id, &d) in ids.iter().zip(&out) {
                assert_eq!(d, (kernels().l2_sq)(&q, vec_at(&data, dim, id)), "dim={dim}");
            }
            dot_batch(&q, &ids, &data, dim, &mut out);
            for (&id, &d) in ids.iter().zip(&out) {
                assert_eq!(d, (kernels().dot)(&q, vec_at(&data, dim, id)), "dim={dim}");
            }
        }
    }

    #[test]
    fn metric_batch_matches_metric_distance() {
        let mut rng = Rng::new(0x3E7);
        let dim = 33;
        let n = 64;
        let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
        let ids: Vec<u32> = (0..n as u32).collect();
        let mut out = Vec::new();
        for metric in [Metric::L2, Metric::Angular, Metric::Ip] {
            distance_batch(metric, &q, &ids, &data, dim, &mut out);
            for (&id, &d) in ids.iter().zip(&out) {
                assert_eq!(d, metric.distance(&q, vec_at(&data, dim, id)), "{metric:?}");
            }
        }
    }

    #[test]
    fn prefetch_schedule_is_result_invariant() {
        // lookahead/locality only prefetch — outputs must be bitwise
        // identical for every schedule (including disabled).
        let mut rng = Rng::new(0xFE7C);
        let dim = 96;
        let n = 80;
        let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
        let ids: Vec<u32> = (0..n as u32).collect();
        let mut want = Vec::new();
        distance_batch_with(Metric::L2, &q, &ids, &data, dim, 0, 3, &mut want);
        for (lookahead, locality) in [(1usize, 1i32), (4, 3), (16, 0), (100, 2)] {
            let mut got = Vec::new();
            distance_batch_with(Metric::L2, &q, &ids, &data, dim, lookahead, locality, &mut got);
            assert_eq!(got, want, "lookahead={lookahead} locality={locality}");
        }
    }

    #[test]
    fn empty_ids_and_empty_vectors() {
        let mut out = vec![1.0f32; 4];
        l2_sq_batch(&[1.0], &[], &[0.0, 2.0], 1, &mut out);
        assert!(out.is_empty());
        // Zero-length vectors: distance 0 / dot 0.
        assert_eq!((kernels().l2_sq)(&[], &[]), 0.0);
        assert_eq!((kernels().dot)(&[], &[]), 0.0);
    }

    fn random_codes(n: usize, rng: &mut Rng) -> Vec<i8> {
        (0..n).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect()
    }

    #[test]
    fn i8_dispatch_selects_a_kernel() {
        let k = kernels_i8();
        assert!(k.name == "avx2" || k.name == "portable32");
        assert_eq!(kernels_i8().name, k.name);
    }

    #[test]
    fn i8_dispatched_exactly_equals_portable() {
        // Integer accumulation: SIMD and portable must agree EXACTLY, at
        // every length straddling the 16/32-lane boundaries — including the
        // extreme code values where an i8-width accumulator would wrap.
        let mut rng = Rng::new(0x18D);
        for dim in [
            1usize, 2, 3, 7, 8, 15, 16, 17, 31, 32, 33, 48, 63, 64, 65, 100, 127, 128, 129, 200,
            784, 960,
        ] {
            let a = random_codes(dim, &mut rng);
            let b = random_codes(dim, &mut rng);
            assert_eq!(
                (kernels_i8().l2_sq)(&a, &b),
                portable_i8::l2_sq(&a, &b),
                "l2_sq_i8 dim={dim}"
            );
            assert_eq!(
                (kernels_i8().dot)(&a, &b),
                portable_i8::dot(&a, &b),
                "dot_i8 dim={dim}"
            );
        }
        // Saturation corners: all-extreme codes maximize every partial sum.
        for dim in [32usize, 960] {
            let lo = vec![-127i8; dim];
            let hi = vec![127i8; dim];
            assert_eq!((kernels_i8().l2_sq)(&lo, &hi), portable_i8::l2_sq(&lo, &hi));
            assert_eq!((kernels_i8().dot)(&lo, &hi), portable_i8::dot(&lo, &hi));
        }
    }

    #[test]
    fn i8_batch_exactly_equals_per_pair() {
        let mut rng = Rng::new(0x18BA);
        for dim in [1usize, 3, 16, 33, 128] {
            let n = 90;
            let codes = random_codes(n * dim, &mut rng);
            let q = random_codes(dim, &mut rng);
            let ids: Vec<u32> = (0..n as u32).rev().step_by(3).chain([0, 0]).collect();
            let mut out = Vec::new();
            l2_sq_i8_batch(&q, &ids, &codes, dim, &mut out);
            assert_eq!(out.len(), ids.len());
            for (&id, &d) in ids.iter().zip(&out) {
                assert_eq!(d, (kernels_i8().l2_sq)(&q, code_at(&codes, dim, id)), "dim={dim}");
            }
            dot_i8_batch(&q, &ids, &codes, dim, &mut out);
            for (&id, &d) in ids.iter().zip(&out) {
                assert_eq!(d, (kernels_i8().dot)(&q, code_at(&codes, dim, id)), "dim={dim}");
            }
        }
    }

    #[test]
    fn quant_batch_schedule_is_result_invariant() {
        let mut rng = Rng::new(0x18FE);
        let dim = 96;
        let n = 70;
        let codes = random_codes(n * dim, &mut rng);
        let q = random_codes(dim, &mut rng);
        let ids: Vec<u32> = (0..n as u32).collect();
        let scale = 0.0173;
        for metric in [Metric::L2, Metric::Angular, Metric::Ip] {
            let mut want = Vec::new();
            quant_distance_batch_with(metric, &q, &ids, &codes, dim, scale, 0, 3, &mut want);
            for (lookahead, locality) in [(1usize, 1i32), (4, 3), (16, 0), (100, 2)] {
                let mut got = Vec::new();
                quant_distance_batch_with(
                    metric, &q, &ids, &codes, dim, scale, lookahead, locality, &mut got,
                );
                assert_eq!(got, want, "{metric:?} lookahead={lookahead} locality={locality}");
            }
        }
    }

    #[test]
    fn i8_empty_ids_and_empty_codes() {
        let mut out = vec![7i32; 4];
        l2_sq_i8_batch(&[1], &[], &[0, 2], 1, &mut out);
        assert!(out.is_empty());
        assert_eq!((kernels_i8().l2_sq)(&[], &[]), 0);
        assert_eq!((kernels_i8().dot)(&[], &[]), 0);
    }

    // --- PQ ADC fast-scan ---------------------------------------------

    fn random_pq_lut(m: usize, rng: &mut Rng) -> PqLut {
        let raw: Vec<f32> = (0..m * 16).map(|_| rng.next_gaussian_f32().abs() * 3.0).collect();
        PqLut::quantize(&raw, m, 0.0)
    }

    fn random_rows(n: usize, row_bytes: usize, rng: &mut Rng) -> Vec<u8> {
        (0..n * row_bytes).map(|_| (rng.next_u64() & 0xFF) as u8).collect()
    }

    /// Position-major block from 32 row-major rows — the layout
    /// `anns::store::pq::scatter_row` maintains for the IVF cells.
    fn transpose_block(rows: &[u8], row_bytes: usize) -> Vec<u8> {
        assert_eq!(rows.len(), PQ_BLOCK * row_bytes);
        let mut block = vec![0u8; rows.len()];
        for s in 0..PQ_BLOCK {
            for p in 0..row_bytes {
                block[p * PQ_BLOCK + s] = rows[s * row_bytes + p];
            }
        }
        block
    }

    #[test]
    fn pq_block_kernel_exactly_equals_portable_oracle() {
        // The dispatched (AVX2 on this hardware) block kernel, the scalar
        // block form, and 32 per-row oracle calls must agree exactly —
        // across even/odd m, the mp-padding corner, and the full m range
        // the u16 accumulator bound covers.
        let mut rng = Rng::new(0xADC0);
        for m in [1usize, 2, 3, 5, 8, 13, 16, 32, 64, 100, 128, 256] {
            let lut = random_pq_lut(m, &mut rng);
            let rows = random_rows(PQ_BLOCK, lut.row_bytes(), &mut rng);
            let block = transpose_block(&rows, lut.row_bytes());
            let mut got = [0u32; PQ_BLOCK];
            (kernels_pq().block)(&lut, &block, &mut got);
            let mut portable = [0u32; PQ_BLOCK];
            portable_pq::adc_block(&lut, &block, &mut portable);
            assert_eq!(got, portable, "m={m}");
            for s in 0..PQ_BLOCK {
                let row = &rows[s * lut.row_bytes()..(s + 1) * lut.row_bytes()];
                assert_eq!(got[s], pq_adc(&lut, row), "m={m} slot={s}");
            }
        }
    }

    #[test]
    fn pq_block_kernel_saturation_corner() {
        // Every table entry quantizes to 255 for nonzero nibbles; at
        // m = 256 (the accumulator bound) the per-row sum is 256·255 =
        // 65280 — the u16 lanes must not wrap.
        let m = 256;
        let raw: Vec<f32> = (0..m * 16).map(|i| if i % 16 == 0 { 0.0 } else { 1.0 }).collect();
        let lut = PqLut::quantize(&raw, m, 0.0);
        let rows = vec![0x11u8; PQ_BLOCK * lut.row_bytes()]; // all nibbles = 1
        let block = transpose_block(&rows, lut.row_bytes());
        let mut got = [0u32; PQ_BLOCK];
        (kernels_pq().block)(&lut, &block, &mut got);
        assert_eq!(got, [m as u32 * 255; PQ_BLOCK]);
        assert_eq!(pq_adc(&lut, &rows[..lut.row_bytes()]), m as u32 * 255);
    }

    #[test]
    fn pq_batch_bitwise_identical_to_per_pair() {
        let mut rng = Rng::new(0xADC1);
        for m in [1usize, 3, 8, 17, 48] {
            let lut = random_pq_lut(m, &mut rng);
            let n = 77;
            let codes = random_rows(n, lut.row_bytes(), &mut rng);
            let ids: Vec<u32> = (0..n as u32).rev().step_by(2).chain([0, 0]).collect();
            let mut out = Vec::new();
            pq_adc_batch(&lut, &ids, &codes, &mut out);
            assert_eq!(out.len(), ids.len());
            for (&id, &d) in ids.iter().zip(&out) {
                let row = &codes[id as usize * lut.row_bytes()..(id as usize + 1) * lut.row_bytes()];
                // assert_eq on f32: bitwise identity, not approximation.
                assert_eq!(d, lut.decode(pq_adc(&lut, row)), "m={m} id={id}");
            }
        }
    }

    #[test]
    fn pq_batch_schedule_is_result_invariant() {
        let mut rng = Rng::new(0xADC2);
        let lut = random_pq_lut(12, &mut rng);
        let n = 64;
        let codes = random_rows(n, lut.row_bytes(), &mut rng);
        let ids: Vec<u32> = (0..n as u32).collect();
        let mut want = Vec::new();
        pq_adc_batch_with(&lut, &ids, &codes, 0, 3, &mut want);
        for (lookahead, locality) in [(1usize, 1i32), (4, 3), (16, 0), (100, 2)] {
            let mut got = Vec::new();
            pq_adc_batch_with(&lut, &ids, &codes, lookahead, locality, &mut got);
            assert_eq!(got, want, "lookahead={lookahead} locality={locality}");
        }
    }

    #[test]
    fn pq_lut_quantization_shape_and_degenerate_tables() {
        // Odd m pads a phantom all-zero table; constant tables quantize
        // to delta = 0 and decode to the exact bias.
        let lut = PqLut::quantize(&vec![2.5f32; 5 * 16], 5, 1.0);
        assert_eq!(lut.row_bytes(), 3);
        assert_eq!(&lut.tables()[5 * 16..], &[0u8; 16][..]);
        let row = [0x31u8, 0x07, 0x0F];
        assert_eq!(pq_adc(&lut, &row), 0);
        // Bias = 5 · 2.5 + metric constant 1.0.
        assert_eq!(lut.decode(pq_adc(&lut, &row)), 13.5);
        let mut empty = Vec::new();
        pq_adc_batch(&lut, &[], &[], &mut empty);
        assert!(empty.is_empty());
    }

    #[test]
    fn pq_dispatch_reports_a_kernel_name() {
        let name = kernels_pq().name;
        assert!(name == "avx2-fastscan" || name == "portable-fastscan", "{name}");
    }
}
