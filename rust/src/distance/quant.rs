//! Int8 scalar quantization (SQ8) — the GLASS "quantized preliminary
//! search" substrate (§2.3 of the paper).
//!
//! Vectors are quantized per-dataset with a symmetric linear code:
//! `q_i = round(x_i / scale)` clipped to `[-127, 127]`, where `scale` is
//! chosen from a high quantile of |x| over a sample (robust to outliers).
//! Distances are computed in i32 and mapped back by the appropriate power
//! of `scale`. The quantized estimates drive graph traversal; survivors are
//! re-ranked in full precision (optionally through the AOT Pallas rerank
//! artifact) — the asymmetric-refinement pattern HNSW libraries use.
//!
//! The i8 kernels are runtime-dispatched like the f32 ones
//! ([`crate::distance::simd::kernels_i8`]: AVX2 `pmaddwd`-shaped with a
//! portable 32-wide fallback). Because they accumulate in i32, the
//! dispatched, portable, and one-to-many batch forms
//! ([`QuantizedStore::distance_batch`]) produce **exactly** the same
//! numbers — quantized search results never depend on which path ran.

use crate::anns::store::region::Segment;
use crate::distance::{simd, Metric};

/// A quantized vector store: row-major `[n, dim]` i8 codes + one scale.
/// The codes live behind a [`Segment`], so a snapshot-served store reads
/// them straight out of an mmapped section (zero-copy) and promotes to
/// heap only when the first online insert mutates a row.
#[derive(Clone, Debug)]
pub struct QuantizedStore {
    pub dim: usize,
    pub scale: f32,
    codes: Segment<i8>,
}

impl QuantizedStore {
    /// Quantize `data` (row-major `[n, dim]` f32), fitting the scale from
    /// the data (robust quantile).
    pub fn build(data: &[f32], dim: usize) -> QuantizedStore {
        Self::with_scale(data, dim, choose_scale(data))
    }

    /// Quantize `data` under an **explicit** scale — how a persisted index
    /// restores its store: rows encode with the exact per-element formula
    /// `build`/[`QuantizedStore::append`] use, so re-deriving codes from
    /// the snapshot's frozen scale is bit-identical to the codes the saved
    /// index carried (a re-fit over base+inserted rows generally is not).
    pub fn with_scale(data: &[f32], dim: usize, scale: f32) -> QuantizedStore {
        assert!(dim > 0 && data.len() % dim == 0);
        let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        let codes: Vec<i8> = data
            .iter()
            .map(|&x| (x * inv).round().clamp(-127.0, 127.0) as i8)
            .collect();
        QuantizedStore { dim, scale, codes: codes.into() }
    }

    /// Assemble a store from already-encoded codes — the snapshot-serving
    /// path: the codes segment views an mmapped section directly, so no
    /// re-quantization (or allocation) happens at load. The caller
    /// guarantees the codes were produced under `scale` by the formula
    /// [`QuantizedStore::with_scale`] uses.
    pub fn from_parts(dim: usize, scale: f32, codes: Segment<i8>) -> Result<QuantizedStore, String> {
        if dim == 0 {
            return Err("quantized store dimension is 0".to_string());
        }
        if codes.len() % dim != 0 {
            return Err(format!(
                "quantized codes length {} is not a multiple of dim {dim}",
                codes.len()
            ));
        }
        if !(scale.is_finite() && scale > 0.0) {
            return Err(format!("quantizer scale {scale} is not a positive finite value"));
        }
        Ok(QuantizedStore { dim, scale, codes })
    }

    pub fn len(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.codes.len() / self.dim
        }
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Codes of vector `i`.
    #[inline]
    pub fn code(&self, i: usize) -> &[i8] {
        &self.codes[i * self.dim..(i + 1) * self.dim]
    }

    /// The full row-major `[n, dim]` code matrix — the `codes` argument the
    /// raw batch kernels ([`crate::distance::l2_sq_i8_batch`] /
    /// [`crate::distance::dot_i8_batch`]) take.
    #[inline]
    pub fn codes(&self) -> &[i8] {
        &self.codes
    }

    /// Quantize a query once per search (symmetric computation).
    pub fn encode_query(&self, q: &[f32]) -> Vec<i8> {
        let inv = if self.scale > 0.0 { 1.0 / self.scale } else { 0.0 };
        q.iter()
            .map(|&x| (x * inv).round().clamp(-127.0, 127.0) as i8)
            .collect()
    }

    /// Approximate distance between an encoded query and stored vector `i`,
    /// in the same units as the f32 metric (so thresholds transfer).
    #[inline]
    pub fn distance(&self, metric: Metric, qcode: &[i8], i: usize) -> f32 {
        let code = self.code(i);
        let raw = match metric {
            Metric::L2 => l2_sq_i8(qcode, code),
            Metric::Angular | Metric::Ip => dot_i8(qcode, code),
        };
        map_quant_raw(metric, raw, self.scale * self.scale)
    }

    /// Distances from an encoded query to a gathered id list through the
    /// one-to-many i8 SIMD kernels (prefetch pipelined; clears and refills
    /// `out`, index-aligned with `ids`). **Bitwise identical** to per-pair
    /// [`QuantizedStore::distance`] calls — the raw distance is an exact
    /// i32 and the `scale²` mapping is shared with the per-pair path.
    #[inline]
    pub fn distance_batch(&self, metric: Metric, qcode: &[i8], ids: &[u32], out: &mut Vec<f32>) {
        self.distance_batch_with(
            metric,
            qcode,
            ids,
            simd::BATCH_LOOKAHEAD,
            simd::BATCH_LOCALITY,
            out,
        );
    }

    /// [`QuantizedStore::distance_batch`] with an explicit prefetch
    /// schedule — how the §6 prefetch knobs reach the quantized batched
    /// paths (`lookahead == 0` disables prefetch; results are identical for
    /// every schedule).
    #[inline]
    pub fn distance_batch_with(
        &self,
        metric: Metric,
        qcode: &[i8],
        ids: &[u32],
        lookahead: usize,
        locality: i32,
        out: &mut Vec<f32>,
    ) {
        simd::quant_distance_batch_with(
            metric,
            qcode,
            ids,
            &self.codes,
            self.dim,
            self.scale,
            lookahead,
            locality,
            out,
        );
    }

    /// Append one row encoded with the **frozen** build-time scale (online
    /// insert). New points from the indexed distribution quantize with the
    /// same error profile as the original rows; a heavily drifted stream
    /// warrants a rebuild, which re-fits the scale from scratch.
    pub fn append(&mut self, v: &[f32]) {
        assert_eq!(v.len(), self.dim, "append dimension mismatch");
        let inv = if self.scale > 0.0 { 1.0 / self.scale } else { 0.0 };
        self.codes
            .to_mut()
            .extend(v.iter().map(|&x| (x * inv).round().clamp(-127.0, 127.0) as i8));
    }

    /// Re-encode row `i` in place (slot recycling after consolidation).
    pub fn reencode(&mut self, i: usize, v: &[f32]) {
        assert_eq!(v.len(), self.dim, "reencode dimension mismatch");
        let inv = if self.scale > 0.0 { 1.0 / self.scale } else { 0.0 };
        let (start, end) = (i * self.dim, (i + 1) * self.dim);
        for (c, &x) in self.codes.to_mut()[start..end].iter_mut().zip(v.iter()) {
            *c = (x * inv).round().clamp(-127.0, 127.0) as i8;
        }
    }

    /// Bytes used by the codes (for memory reporting).
    pub fn bytes(&self) -> usize {
        self.codes.len()
    }
}

/// Map a raw i32 code distance into f32 metric units with a precomputed
/// `s2 = scale²`. Shared by the per-pair and batch paths — computing `s2`
/// once and applying one multiply keeps the two bitwise identical (the old
/// per-pair form multiplied by `scale` twice, which rounds differently
/// from a batch-hoisted `scale²`).
#[inline]
pub fn map_quant_raw(metric: Metric, raw: i32, s2: f32) -> f32 {
    match metric {
        Metric::L2 => raw as f32 * s2,
        Metric::Angular => 1.0 - raw as f32 * s2,
        Metric::Ip => -(raw as f32) * s2,
    }
}

/// Robust scale: 99.9th percentile of |x| over a strided sample, / 127.
fn choose_scale(data: &[f32]) -> f32 {
    if data.is_empty() {
        return 1.0;
    }
    let stride = (data.len() / 65_536).max(1);
    let mut sample: Vec<f32> = data.iter().step_by(stride).map(|x| x.abs()).collect();
    sample.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((sample.len() as f64 - 1.0) * 0.999) as usize;
    let q = sample[idx].max(1e-12);
    q / 127.0
}

/// i8 squared-L2 through the runtime-dispatched kernel (AVX2 where
/// detected, 32-wide `pmaddwd`-shaped portable loop otherwise — see
/// [`simd::kernels_i8`]; EXPERIMENTS.md §Perf/L3 records the portable
/// form's measured win over the naive loop).
#[inline]
pub fn l2_sq_i8(a: &[i8], b: &[i8]) -> i32 {
    (simd::kernels_i8().l2_sq)(a, b)
}

/// i8 inner product through the runtime-dispatched kernel.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    (simd::kernels_i8().dot)(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_data(n: usize, dim: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * dim).map(|_| rng.next_gaussian_f32()).collect()
    }

    #[test]
    fn quantized_l2_close_to_exact() {
        let dim = 64;
        let data = random_data(200, dim, 1);
        let store = QuantizedStore::build(&data, dim);
        let q = &data[0..dim];
        let qc = store.encode_query(q);
        let mut max_rel = 0f32;
        for i in 1..200 {
            let exact = crate::distance::l2_sq(q, &data[i * dim..(i + 1) * dim]);
            let approx = store.distance(Metric::L2, &qc, i);
            let rel = (exact - approx).abs() / exact.max(1e-6);
            max_rel = max_rel.max(rel);
        }
        assert!(max_rel < 0.05, "max relative error {max_rel}");
    }

    #[test]
    fn quantized_preserves_ranking_mostly() {
        // SQ8 must keep the true nearest neighbor inside its top-5.
        let dim = 128;
        let n = 500;
        let data = random_data(n, dim, 2);
        let store = QuantizedStore::build(&data, dim);
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let qi = rng.next_below(n);
            let q = &data[qi * dim..(qi + 1) * dim];
            let qc = store.encode_query(q);
            let mut exact: Vec<(f32, usize)> = (0..n)
                .filter(|&i| i != qi)
                .map(|i| (crate::distance::l2_sq(q, &data[i * dim..(i + 1) * dim]), i))
                .collect();
            exact.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let true_nn = exact[0].1;
            let mut approx: Vec<(f32, usize)> = (0..n)
                .filter(|&i| i != qi)
                .map(|i| (store.distance(Metric::L2, &qc, i), i))
                .collect();
            approx.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let top5: Vec<usize> = approx.iter().take(5).map(|x| x.1).collect();
            assert!(top5.contains(&true_nn));
        }
    }

    #[test]
    fn self_distance_zero() {
        let dim = 32;
        let data = random_data(10, dim, 4);
        let store = QuantizedStore::build(&data, dim);
        let qc = store.encode_query(&data[3 * dim..4 * dim]);
        assert_eq!(store.distance(Metric::L2, &qc, 3), 0.0);
    }

    #[test]
    fn i8_kernels_match_naive() {
        let mut rng = Rng::new(5);
        for len in [0usize, 1, 15, 16, 17, 64, 100] {
            let a: Vec<i8> = (0..len).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..len).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
            let l2_naive: i32 = a
                .iter()
                .zip(&b)
                .map(|(x, y)| {
                    let d = *x as i32 - *y as i32;
                    d * d
                })
                .sum();
            let dot_naive: i32 = a.iter().zip(&b).map(|(x, y)| *x as i32 * *y as i32).sum();
            assert_eq!(l2_sq_i8(&a, &b), l2_naive, "len={len}");
            assert_eq!(dot_i8(&a, &b), dot_naive, "len={len}");
        }
    }

    #[test]
    fn append_and_reencode_match_build_encoding() {
        // A row appended (or re-encoded in place) with the frozen scale
        // must be bit-identical to what a from-scratch build of the same
        // data produces — the guarantee that keeps online inserts on the
        // same quantization contract as the original rows.
        let dim = 24;
        let data = random_data(50, dim, 8);
        let extra = random_data(3, dim, 9);
        let mut grown = QuantizedStore::build(&data, dim);
        for row in extra.chunks(dim) {
            grown.append(row);
        }
        assert_eq!(grown.len(), 53);
        let mut all = data.clone();
        all.extend_from_slice(&extra);
        // Same scale => same codes for the appended rows.
        let reference = QuantizedStore::build(&all, dim);
        if (reference.scale - grown.scale).abs() < f32::EPSILON * grown.scale {
            for i in 50..53 {
                assert_eq!(grown.code(i), reference.code(i), "row {i}");
            }
        }
        // reencode == append encoding of the same vector.
        let mut other = grown.clone();
        other.reencode(0, &extra[0..dim]);
        assert_eq!(other.code(0), grown.code(50));
        // with_scale under the frozen scale reproduces the grown store's
        // codes bit-for-bit — the persistence restore path.
        let restored = QuantizedStore::with_scale(&all, dim, grown.scale);
        assert_eq!(restored.scale, grown.scale);
        for i in 0..53 {
            assert_eq!(restored.code(i), grown.code(i), "restored row {i}");
        }
    }

    #[test]
    fn store_accessors() {
        let data = random_data(7, 16, 6);
        let s = QuantizedStore::build(&data, 16);
        assert_eq!(s.len(), 7);
        assert!(!s.is_empty());
        assert_eq!(s.code(6).len(), 16);
        assert_eq!(s.codes().len(), 7 * 16);
        assert_eq!(s.bytes(), 7 * 16);
    }

    #[test]
    fn store_batch_bitwise_identical_to_per_pair_all_metrics() {
        // Odd dim exercises the scalar tails; repeated + reversed ids
        // exercise the gather. f32 equality must be exact (`assert_eq!`):
        // the raw distance is an exact i32 and the scale mapping is shared.
        for dim in [1usize, 3, 17, 33, 64] {
            let n = 60;
            let data = random_data(n, dim, 7 + dim as u64);
            let store = QuantizedStore::build(&data, dim);
            let qc = store.encode_query(&data[0..dim]);
            let ids: Vec<u32> = (0..n as u32).rev().step_by(2).chain([0, 0]).collect();
            let mut out = Vec::new();
            for metric in [Metric::L2, Metric::Angular, Metric::Ip] {
                store.distance_batch(metric, &qc, &ids, &mut out);
                assert_eq!(out.len(), ids.len());
                for (&id, &d) in ids.iter().zip(&out) {
                    assert_eq!(
                        d,
                        store.distance(metric, &qc, id as usize),
                        "{metric:?} dim={dim} id={id}"
                    );
                }
            }
        }
    }

    #[test]
    fn store_batch_schedule_invariant() {
        let dim = 48;
        let data = random_data(40, dim, 9);
        let store = QuantizedStore::build(&data, dim);
        let qc = store.encode_query(&data[0..dim]);
        let ids: Vec<u32> = (0..40).collect();
        let mut want = Vec::new();
        store.distance_batch_with(Metric::L2, &qc, &ids, 0, 3, &mut want);
        for (lookahead, locality) in [(1usize, 1i32), (8, 3), (64, 0)] {
            let mut got = Vec::new();
            store.distance_batch_with(Metric::L2, &qc, &ids, lookahead, locality, &mut got);
            assert_eq!(got, want, "lookahead={lookahead} locality={locality}");
        }
    }
}
