//! Distance kernels for the Rust request path.
//!
//! Graph traversal computes millions of single-pair distances — these stay
//! in Rust (as in GLASS/ParlayANN); only *batch* paths (ground truth, exact
//! rerank) go through the AOT Pallas artifacts via [`crate::runtime`].
//!
//! Conventions match `python/compile/kernels/ref.py` exactly:
//! * `L2`      — squared Euclidean (monotone in true distance; no sqrt),
//! * `Angular` — `1 - <q, b>` on unit vectors (ann-benchmarks angular),
//! * `Ip`      — negated inner product.
//!
//! The f32 kernels live in [`simd`]: explicit AVX2+FMA implementations with
//! a portable 8-wide fallback, selected once at startup into function
//! pointers (`is_x86_feature_detected!` — DESIGN.md §SIMD-Dispatch), plus
//! one-to-many batch kernels ([`l2_sq_batch`]/[`dot_batch`]) that interleave
//! software prefetch with evaluation. The int8 SQ8 path ([`quant`], used by
//! the GLASS quantized beam and the IVF posting-list scan) dispatches the
//! same way ([`simd::kernels_i8`]) with exact-integer batch forms
//! ([`l2_sq_i8_batch`]/[`dot_i8_batch`]/[`quant_distance_batch`]).

pub mod quant;
pub mod simd;

pub use simd::{
    distance_batch, distance_batch_with, dot_batch, dot_i8_batch, kernels_pq, l2_sq_batch,
    l2_sq_i8_batch, pq_adc, pq_adc_batch, pq_adc_batch_with, quant_distance_batch,
    quant_distance_batch_with, PqLut, PQ_BLOCK,
};

/// Distance metric. Mirrors the dataset metric in Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Squared Euclidean distance.
    L2,
    /// Angular distance `1 - cos` over unit-normalized vectors.
    Angular,
    /// Negated inner product (MIPS as min-distance).
    Ip,
}

impl Metric {
    pub fn name(&self) -> &'static str {
        match self {
            Metric::L2 => "l2",
            Metric::Angular => "angular",
            Metric::Ip => "ip",
        }
    }

    pub fn from_name(s: &str) -> Option<Metric> {
        match s {
            "l2" | "euclidean" => Some(Metric::L2),
            "angular" | "cosine" => Some(Metric::Angular),
            "ip" | "dot" => Some(Metric::Ip),
            _ => None,
        }
    }

    /// Whether dataset vectors must be L2-normalized at load time.
    pub fn requires_normalization(&self) -> bool {
        matches!(self, Metric::Angular)
    }

    /// Distance between two vectors under this metric.
    #[inline]
    pub fn distance(&self, a: &[f32], b: &[f32]) -> f32 {
        match self {
            Metric::L2 => l2_sq(a, b),
            Metric::Angular => 1.0 - dot(a, b),
            Metric::Ip => -dot(a, b),
        }
    }

    /// Distances from `q` to each `ids[i]` row of `data` (row-major, `dim`
    /// columns) via the prefetch-pipelined batch kernels. Clears and
    /// refills `out`, index-aligned with `ids`; results are bitwise
    /// identical to calling [`Metric::distance`] per pair.
    #[inline]
    pub fn distance_batch(
        &self,
        q: &[f32],
        ids: &[u32],
        data: &[f32],
        dim: usize,
        out: &mut Vec<f32>,
    ) {
        simd::distance_batch(*self, q, ids, data, dim, out);
    }
}

/// Squared L2 distance through the runtime-dispatched kernel (AVX2+FMA
/// where detected, portable 8-wide otherwise — see [`simd::kernels`]).
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    (simd::kernels().l2_sq)(a, b)
}

/// Inner product through the runtime-dispatched kernel.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    (simd::kernels().dot)(a, b)
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Normalize in place to unit length (no-op on zero vectors).
pub fn normalize(a: &mut [f32]) {
    let n = norm(a);
    if n > 0.0 {
        let inv = 1.0 / n;
        for x in a {
            *x *= inv;
        }
    }
}

/// Software prefetch of the cache line(s) at `data`. `locality` follows the
/// paper's snippets: 3 = keep in L1 (`_MM_HINT_T0`), 2 = L2, 1 = L3,
/// 0 = non-temporal. No-op on non-x86 targets.
#[inline(always)]
pub fn prefetch(data: &[f32], locality: i32) {
    prefetch_ptr(data.as_ptr().cast(), locality);
}

/// Typeless form of [`prefetch`]: hint the cache line at `p` (cache lines
/// have no element type — this is how the i8 code rows are prefetched
/// without reinterpreting them as `&[f32]`). Prefetch only inspects the
/// address, so any pointer value is safe to pass; no-op off x86_64.
#[inline(always)]
pub fn prefetch_ptr(p: *const u8, locality: i32) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_NTA, _MM_HINT_T0, _MM_HINT_T1, _MM_HINT_T2};
        let p = p as *const i8;
        match locality {
            3 => _mm_prefetch(p, _MM_HINT_T0),
            2 => _mm_prefetch(p, _MM_HINT_T1),
            1 => _mm_prefetch(p, _MM_HINT_T2),
            _ => _mm_prefetch(p, _MM_HINT_NTA),
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (p, locality);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_l2(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }

    fn naive_dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn l2_matches_naive_all_lengths() {
        let mut rng = crate::util::rng::Rng::new(1);
        for len in [0, 1, 3, 7, 8, 9, 15, 16, 25, 100, 128, 960] {
            let a: Vec<f32> = (0..len).map(|_| rng.next_gaussian_f32()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.next_gaussian_f32()).collect();
            let got = l2_sq(&a, &b);
            let want = naive_l2(&a, &b);
            assert!((got - want).abs() <= 1e-4 * (1.0 + want.abs()), "len={len}");
        }
    }

    #[test]
    fn dot_matches_naive_all_lengths() {
        let mut rng = crate::util::rng::Rng::new(2);
        for len in [0, 1, 5, 8, 13, 64, 100, 256] {
            let a: Vec<f32> = (0..len).map(|_| rng.next_gaussian_f32()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.next_gaussian_f32()).collect();
            let got = dot(&a, &b);
            let want = naive_dot(&a, &b);
            assert!((got - want).abs() <= 1e-4 * (1.0 + want.abs()), "len={len}");
        }
    }

    #[test]
    fn metric_semantics() {
        let a = [1.0, 0.0];
        let b = [0.0, 1.0];
        assert_eq!(Metric::L2.distance(&a, &b), 2.0);
        assert_eq!(Metric::Angular.distance(&a, &b), 1.0);
        assert_eq!(Metric::Ip.distance(&a, &b), 0.0);
        assert_eq!(Metric::L2.distance(&a, &a), 0.0);
        assert_eq!(Metric::Angular.distance(&a, &a), 0.0);
    }

    #[test]
    fn metric_names_roundtrip() {
        for m in [Metric::L2, Metric::Angular, Metric::Ip] {
            assert_eq!(Metric::from_name(m.name()), Some(m));
        }
        assert_eq!(Metric::from_name("euclidean"), Some(Metric::L2));
        assert_eq!(Metric::from_name("bogus"), None);
    }

    #[test]
    fn normalize_unit_length() {
        let mut v = vec![3.0, 4.0];
        normalize(&mut v);
        assert!((norm(&v) - 1.0).abs() < 1e-6);
        let mut z = vec![0.0; 4];
        normalize(&mut z); // must not NaN
        assert!(z.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn prefetch_is_safe() {
        let v = vec![0f32; 64];
        prefetch(&v, 3);
        prefetch(&v, 0);
    }

    #[test]
    fn prefetch_ptr_is_safe_for_any_length() {
        // Including buffers shorter than one f32 — the case the old GLASS
        // code-prefetch slice reinterpretation got wrong.
        for len in 0..5usize {
            let v = vec![0i8; len];
            for locality in 0..4 {
                prefetch_ptr(v.as_ptr().cast(), locality);
            }
        }
    }
}
