//! Error type + context plumbing (the `anyhow` substitute).
//!
//! Offline builds cannot pull `anyhow` (DESIGN.md §8), so this module
//! provides the slice of its API the crate uses: an opaque [`Error`] that
//! any `std::error::Error` converts into via `?`, a [`Context`] extension
//! for `Result`/`Option`, and the [`crate::bail!`]/[`crate::ensure!`]
//! macros. `{:#}` formatting renders the full cause chain, `{}` only the
//! outermost message — matching the conventions call sites rely on.

use std::fmt;

/// Crate-wide result alias (re-exported as [`crate::Result`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a message plus an optional chain of causes.
///
/// Deliberately does **not** implement `std::error::Error`, so the blanket
/// `From<E: std::error::Error>` impl below can coexist with the reflexive
/// `From<Error> for Error` from core (the same trick `anyhow` uses).
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Build an error from any displayable message (e.g. the `String`
    /// errors of [`crate::util::json::parse`]).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            cause: None,
        }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error {
            msg: ctx.to_string(),
            cause: Some(Box::new(self)),
        }
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.cause.as_deref();
            Some(cur.msg.as_str())
        })
    }
}

impl fmt::Display for Error {
    /// `{}` prints the outermost message; `{:#}` the full chain separated
    /// by `": "` (anyhow's alternate convention).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, m) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    /// `?`-conversion from any std error, flattening its source chain.
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut out: Option<Box<Error>> = None;
        for msg in msgs.into_iter().rev() {
            out = Some(Box::new(Error { msg, cause: out }));
        }
        *out.expect("at least one message")
    }
}

/// Context extension for fallible values (`anyhow::Context` equivalent).
pub trait Context<T> {
    /// Attach a fixed context message on failure.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Attach a lazily-built context message on failure.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Return early with a formatted [`Error`] unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing file"));
    }

    #[test]
    fn context_wraps_and_alternate_prints_chain() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening dataset").unwrap_err();
        assert_eq!(format!("{e}"), "opening dataset");
        let full = format!("{e:#}");
        assert!(full.starts_with("opening dataset: "), "{full}");
        assert!(full.contains("missing file"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("metric").unwrap_err();
        assert_eq!(e.to_string(), "metric");
        assert_eq!(Some(1u32).with_context(|| "x").unwrap(), 1);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("negative input -1"));
        assert!(f(101).unwrap_err().to_string().contains("too big: 101"));
    }

    #[test]
    fn debug_renders_cause_chain() {
        let e = Error::msg("inner").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("inner"));
    }
}
