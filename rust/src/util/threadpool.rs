//! Scoped data-parallel helpers (the `rayon` substitute).
//!
//! ANNS benchmarking needs two patterns: chunked `parallel_for` over index
//! ranges (graph build, batch queries) and a `parallel_map` that preserves
//! order. Both are built on `std::thread::scope`, sized by
//! [`effective_threads`]. On a single-core sandbox they degrade gracefully
//! to sequential execution with zero thread overhead.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: `CRINN_THREADS` env override, else the
/// machine's available parallelism.
pub fn effective_threads() -> usize {
    if let Ok(s) = std::env::var("CRINN_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f(start, end)` over `[0, n)` split into contiguous chunks across
/// threads (sized by [`effective_threads`], i.e. the `CRINN_THREADS`
/// override). `f` must be `Sync`; chunks are claimed dynamically (atomic
/// cursor) so uneven work self-balances.
pub fn parallel_for<F>(n: usize, min_chunk: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    parallel_for_threads(n, min_chunk, effective_threads(), f);
}

/// [`parallel_for`] with an explicit worker count — the seam tests use to
/// exercise the threaded path without touching process environment.
/// `threads <= 1` runs `f(0, n)` on the calling thread.
pub fn parallel_for_threads<F>(n: usize, min_chunk: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    if threads <= 1 || n <= min_chunk {
        f(0, n);
        return;
    }
    let chunk = min_chunk.max(n / (threads * 4)).max(1);
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads.min(n.div_ceil(chunk)) {
            s.spawn(|| loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                f(start, end);
            });
        }
    });
}

/// Order-preserving parallel map over `0..n`.
pub fn parallel_map<T, F>(n: usize, min_chunk: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_threads(n, min_chunk, effective_threads(), f)
}

/// [`parallel_map`] with an explicit worker count. Output order is by
/// index regardless of which thread computed which chunk, so results are
/// identical for every `threads` value (given a deterministic `f`).
pub fn parallel_map_threads<T, F>(n: usize, min_chunk: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots = SyncSlice(out.as_mut_ptr());
        let slots_ref = &slots; // capture the Sync wrapper, not the raw ptr
        parallel_for_threads(n, min_chunk, threads, move |start, end| {
            for i in start..end {
                // SAFETY: each index is written by exactly one chunk owner.
                unsafe { *slots_ref.0.add(i) = f(i) };
            }
        });
    }
    out
}

/// Pointer wrapper asserting disjoint-index writes are safe to share.
struct SyncSlice<T>(*mut T);
unsafe impl<T: Send> Sync for SyncSlice<T> {}
unsafe impl<T: Send> Send for SyncSlice<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_every_index_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, 64, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_empty_and_tiny() {
        parallel_for(0, 8, |_, _| panic!("must not run"));
        let sum = AtomicUsize::new(0);
        parallel_for(3, 8, |s, e| {
            sum.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(1000, 16, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn threads_env_override() {
        // effective_threads is >= 1 regardless of environment.
        assert!(effective_threads() >= 1);
    }

    #[test]
    fn explicit_thread_counts_agree() {
        let want: Vec<usize> = (0..500).map(|i| i * 3 + 1).collect();
        for threads in [1, 2, 4, 8] {
            let got = parallel_map_threads(500, 8, threads, |i| i * 3 + 1);
            assert_eq!(got, want, "threads={threads}");
        }
    }
}
