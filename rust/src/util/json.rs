//! Minimal JSON value model, writer, and recursive-descent parser.
//!
//! Exists because `serde`/`serde_json` are unavailable offline. Scope: what
//! the crate needs — reading `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and writing report/checkpoint files. Numbers are
//! parsed as f64; no trailing commas / comments / BOM handling.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics on non-objects (programmer error).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience: `obj.path("a").path("b")` style nested lookup.
    pub fn path<'a>(&'a self, keys: &[&str]) -> Option<&'a Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns an error with byte offset on failure.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|e| e.to_string())?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] got {other:?} at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} got {other:?} at {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\n"}], "c": null}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x\n")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn writer_escapes() {
        let mut o = Json::obj();
        o.set("k", Json::from("a\"b\\c\nd"));
        let s = o.to_string();
        assert_eq!(parse(&s).unwrap(), o);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn float_array_roundtrip() {
        let xs: Vec<f64> = vec![0.1, -2.0, 1e-9, 12345.0];
        let j = Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect());
        let back = parse(&j.to_string()).unwrap();
        let ys: Vec<f64> = back
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        for (a, b) in xs.iter().zip(&ys) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn unicode_strings() {
        let v = parse("\"héllo ≤ wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ≤ wörld"));
        let v = parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }
}
