//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core.
//!
//! The paper's Critical Requirements (Table 1) demand deterministic,
//! reproducible results across runs; every stochastic component in the crate
//! (dataset generation, HNSW level sampling, GRPO action sampling, …) draws
//! from this generator with an explicit seed.

/// xoshiro256++ generator (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller output.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            gauss_spare: None,
        }
    }

    /// Derive an independent stream (for per-thread / per-shard use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// The raw xoshiro256++ state — lets a persisted index resume its
    /// stochastic stream (e.g. HNSW insert-level sampling) exactly where
    /// the snapshot left off. The Box–Muller spare is deliberately not
    /// part of the state: [`Rng::from_state`] restarts with an empty
    /// cache, which only matters to interleaved gaussian draws (none of
    /// the persisted streams use them).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from [`Rng::state`]. An all-zero state is the
    /// one degenerate xoshiro orbit (constant output), so it falls back to
    /// the fixed default seed instead — a hostile snapshot cannot wedge
    /// the level sampler.
    pub fn from_state(s: [u64; 4]) -> Self {
        if s == [0; 4] {
            return Rng::new(0);
        }
        Rng {
            s,
            gauss_spare: None,
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`, 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire-ish via widening multiply).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        // u1 in (0,1] to keep ln finite.
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Standard normal f32.
    #[inline]
    pub fn next_gaussian_f32(&mut self) -> f32 {
        self.next_gaussian() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n assumed; rejection).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let i = self.next_below(n);
            if seen.insert(i) {
                out.push(i);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(100, 10), (10, 10), (50, 40)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut r = Rng::new(42);
        for _ in 0..17 {
            r.next_u64();
        }
        let mut resumed = Rng::from_state(r.state());
        for _ in 0..64 {
            assert_eq!(r.next_u64(), resumed.next_u64());
        }
        // The degenerate all-zero orbit is rejected, not reproduced.
        let mut z = Rng::from_state([0; 4]);
        assert_ne!(z.next_u64(), 0);
    }
}
