//! Substrates rebuilt from scratch.
//!
//! The offline environment has no `rand`, `rayon`, `serde`, `clap`,
//! `criterion`, or `anyhow`, so this module provides the pieces of those
//! the rest of the crate needs: a counter-based PRNG ([`rng`]), a scoped
//! parallel-for ([`threadpool`]), a JSON writer/parser ([`json`]), a flag
//! parser ([`cli`]), a measurement harness ([`bench`]), and an error type
//! with context chaining ([`error`]).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod rng;
pub mod threadpool;

/// `std::hint::black_box` re-export so benches don't reach into `std::hint`
/// everywhere (and so a fallback is centralized if the hint ever changes).
pub use std::hint::black_box;
