//! Measurement harness (the `criterion` substitute).
//!
//! Provides warmup + repeated timing with robust statistics, used both by
//! the `rust/benches/*` targets (compiled with `harness = false`) and by
//! the QPS measurements inside `eval::sweep` (where per-query latencies
//! feed p50/p99 service metrics).

use std::time::Instant;

/// Summary statistics over a set of per-iteration durations (seconds).
#[derive(Debug, Clone, Default)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
    pub total: f64,
}

impl Stats {
    /// Build from raw per-iteration seconds.
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        if xs.is_empty() {
            return Stats::default();
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let total: f64 = xs.iter().sum();
        let mean = total / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| -> f64 {
            let idx = ((n as f64 - 1.0) * p).round() as usize;
            xs[idx.min(n - 1)]
        };
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            max: xs[n - 1],
            total,
        }
    }

    /// Iterations per second implied by the mean.
    pub fn rate(&self) -> f64 {
        if self.mean > 0.0 {
            1.0 / self.mean
        } else {
            0.0
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed ones.
pub fn time_iters<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Stats::from_samples(samples)
}

/// Time a single run of `f`, returning (seconds, result).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (f64, T) {
    let t = Instant::now();
    let out = f();
    (t.elapsed().as_secs_f64(), out)
}

/// Adaptive measurement: run batches of `f` until at least `min_time_s`
/// elapsed and `min_iters` iterations accumulated. Returns per-iteration
/// stats. This is how the benches keep wall-clock bounded regardless of
/// workload cost.
pub fn time_adaptive<F: FnMut()>(min_time_s: f64, min_iters: usize, mut f: F) -> Stats {
    // Warmup one iteration (pays lazy-init costs).
    f();
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed().as_secs_f64() < min_time_s {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if samples.len() > 1_000_000 {
            break;
        }
    }
    Stats::from_samples(samples)
}

/// Pretty-print a benchmark row (used by the custom bench targets).
pub fn report_row(name: &str, s: &Stats) {
    println!(
        "{name:<44} mean {:>10}  p50 {:>10}  p99 {:>10}  ({} iters)",
        fmt_duration(s.mean),
        fmt_duration(s.p50),
        fmt_duration(s.p99),
        s.n
    );
}

/// Bytes → MiB, for memory report lines. One definition: `main.rs` and the
/// eval harness previously each hard-coded the 1048576 divisor.
pub fn mib(bytes: usize) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

/// Human-format a duration in seconds.
pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles_ordered() {
        let s = Stats::from_samples((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.n, 100);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn stats_empty_is_zeroed() {
        let s = Stats::from_samples(vec![]);
        assert_eq!(s.n, 0);
        assert_eq!(s.rate(), 0.0);
    }

    #[test]
    fn time_iters_counts() {
        let mut calls = 0;
        let s = time_iters(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn adaptive_respects_min_iters() {
        let s = time_adaptive(0.0, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.n >= 10);
    }

    #[test]
    fn mib_conversion() {
        assert_eq!(mib(0), 0.0);
        assert_eq!(mib(1 << 20), 1.0);
        assert_eq!(mib(3 * (1 << 20) + (1 << 19)), 3.5);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(2.0).ends_with('s'));
        assert!(fmt_duration(2e-3).ends_with("ms"));
        assert!(fmt_duration(2e-6).ends_with("us"));
        assert!(fmt_duration(2e-9).ends_with("ns"));
    }
}
