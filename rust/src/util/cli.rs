//! Flag parser (the `clap` substitute).
//!
//! Grammar: `crinn <subcommand> [positional...] [--key value | --flag]`.
//! Typed getters with defaults keep call sites terse; unknown-flag detection
//! catches typos (a real footgun in benchmark sweeps).

use std::collections::{BTreeMap, BTreeSet};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (after the binary name).
    pub command: Option<String>,
    /// Remaining non-flag tokens.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags read so far — for unknown-flag reporting.
    seen: std::cell::RefCell<BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of tokens (not including the binary name).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().insert(key.to_string());
    }

    /// Raw string flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list of usize (e.g. `--ef 10,20,40`).
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .map(|t| {
                    t.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{key}: bad integer {t:?}"))
                })
                .collect(),
        }
    }

    /// Flags present on the command line but never read by the command.
    pub fn unknown_flags(&self) -> Vec<String> {
        let seen = self.seen.borrow();
        self.flags
            .keys()
            .filter(|k| !seen.contains(*k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("bench fig1 extra");
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["fig1", "extra"]);
    }

    #[test]
    fn key_value_forms() {
        let a = parse("run --n 100 --name=sift --verbose");
        assert_eq!(a.usize_or("n", 0), 100);
        assert_eq!(a.str_or("name", ""), "sift");
        assert!(a.bool_flag("verbose"));
        assert!(!a.bool_flag("quiet"));
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("x --ef 10,20,40");
        assert_eq!(a.usize_list("ef", &[1]), vec![10, 20, 40]);
        assert_eq!(a.usize_list("absent", &[7, 8]), vec![7, 8]);
        assert_eq!(a.f64_or("tau", 0.5), 0.5);
    }

    #[test]
    fn unknown_flags_reported() {
        let a = parse("x --used 1 --typo 2");
        let _ = a.get("used");
        assert_eq!(a.unknown_flags(), vec!["typo".to_string()]);
    }

    #[test]
    #[should_panic]
    fn bad_integer_panics() {
        let a = parse("x --n abc");
        let _ = a.usize_or("n", 0);
    }
}
