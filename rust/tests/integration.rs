//! Cross-layer integration tests: Rust coordinator ⇄ PJRT artifacts ⇄
//! ANNS engines ⇄ eval harness, on real (small) workloads.

use crinn::anns::VectorSet;
use crinn::dataset::synth;
use crinn::distance::Metric;
use crinn::variants::VariantConfig;
use std::sync::Arc;

fn engine() -> Option<crinn::runtime::Engine> {
    let dir = crinn::runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    match crinn::runtime::Engine::new(&dir) {
        Ok(e) => Some(e),
        Err(e) if format!("{e:#}").contains("offline stub") => {
            eprintln!("skipping: PJRT backend is the offline stub");
            None
        }
        Err(e) => panic!("engine failed with artifacts present: {e:#}"),
    }
}

/// L1⇄L3: the Pallas scan artifact and the Rust scalar path must agree on
/// exact ground truth for every paper dataset dimension.
#[test]
fn pjrt_ground_truth_matches_rust_across_dims() {
    let Some(e) = engine() else { return };
    for name in ["sift-128-euclidean", "glove-25-angular"] {
        let sp = synth::spec(name).unwrap();
        let ds = synth::generate_counts(sp, 600, 10, 5);
        let got = e
            .brute_force_topk(ds.metric, &ds.queries, &ds.base, ds.dim, 10)
            .unwrap();
        let want =
            crinn::dataset::gt::brute_force_topk(&ds.base, &ds.queries, ds.dim, ds.metric, 10);
        let agree = got.iter().zip(&want).filter(|(a, b)| a == b).count();
        assert!(
            agree >= 9,
            "{name}: only {agree}/10 queries agree between PJRT and Rust"
        );
    }
}

/// L1⇄L3 rerank: PJRT rerank distances must reproduce the Rust rerank
/// ordering inside the GLASS pipeline.
#[test]
fn pjrt_rerank_consistent_with_glass() {
    let Some(e) = engine() else { return };
    let sp = synth::spec("sift-128-euclidean").unwrap();
    let mut ds = synth::generate_counts(sp, 1500, 20, 6);
    ds.compute_ground_truth(10);
    let idx = crinn::anns::glass::GlassIndex::build(
        VectorSet::from_dataset(&ds),
        VariantConfig::glass_baseline(),
        7,
    );
    let dim = ds.dim;
    for qi in 0..5 {
        let q = ds.query_vec(qi);
        let cands = idx.candidates_for_rerank(q, 10, 64);
        let c = cands.len().min(e.manifest.rerank_cands);
        let mut gathered = vec![0f32; c * dim];
        for (ci, &id) in cands.iter().take(c).enumerate() {
            gathered[ci * dim..(ci + 1) * dim].copy_from_slice(ds.base_vec(id as usize));
        }
        let dists = e.rerank(ds.metric, q, 1, &gathered, c, dim).unwrap();
        // PJRT distances must match Rust distances on the same pairs.
        for (ci, &id) in cands.iter().take(c).enumerate() {
            let want = ds.metric.distance(q, ds.base_vec(id as usize));
            assert!(
                (dists[0][ci] - want).abs() < 1e-2 * (1.0 + want.abs()),
                "q{qi} cand{ci}: pjrt {} vs rust {want}",
                dists[0][ci]
            );
        }
    }
}

/// Full Figure-1-shaped comparison on one dataset: CRINN's discovered
/// configuration must not lose to the GLASS baseline in window AUC
/// (the paper's §5.1 CRINN-vs-GLASS claim, at sandbox scale).
#[test]
fn crinn_beats_or_matches_glass_in_reward_window() {
    let sp = synth::spec("sift-128-euclidean").unwrap();
    let mut ds = synth::generate_counts(sp, 4000, 60, 7);
    ds.compute_ground_truth(10);
    let ef_grid = [16, 24, 32, 48, 64, 96, 128];
    let mut aucs = std::collections::HashMap::new();
    for (label, cfg) in [
        ("glass", VariantConfig::glass_baseline()),
        ("crinn", VariantConfig::crinn_full()),
    ] {
        let idx = crinn::anns::glass::GlassIndex::build(
            VectorSet::from_dataset(&ds),
            cfg,
            7,
        );
        let sweep = crinn::eval::sweep_index(&idx, &ds, 10, &ef_grid, 0.0);
        aucs.insert(
            label,
            crinn::crinn::reward::window_auc(&sweep.points, 0.85, 0.95),
        );
    }
    let glass = aucs["glass"];
    let crinn_auc = aucs["crinn"];
    assert!(glass > 0.0, "glass never reached the window");
    assert!(
        crinn_auc >= glass * 0.9,
        "crinn {crinn_auc:.0} vs glass {glass:.0} — discovered config regressed"
    );
}

/// Serving stack over a real index: batched, sharded, concurrent — recall
/// must survive the full coordinator path.
#[test]
fn coordinator_end_to_end_recall() {
    let sp = synth::spec("demo-64").unwrap();
    let mut ds = synth::generate_counts(sp, 2000, 50, 8);
    ds.compute_ground_truth(10);
    let ds = Arc::new(ds);
    // The router is itself an AnnIndex (batched fan-out, merge on the
    // shard-carried exact distances), so it serves without a wrapper.
    let router = crinn::coordinator::ShardedRouter::build_glass(
        &ds,
        &VariantConfig::crinn_full(),
        2,
        7,
    );
    let server = crinn::coordinator::Server::start(Arc::new(router), Default::default());
    let h = server.handle();
    let mut recall = 0.0;
    for qi in 0..ds.n_queries() {
        let resp = h.query(ds.query_vec(qi).to_vec(), 10, 96).unwrap();
        recall += crinn::dataset::gt::recall_at_k(&resp.ids, &ds.gt[qi], 10);
    }
    recall /= ds.n_queries() as f64;
    let snap = server.shutdown();
    assert!(recall > 0.85, "served recall {recall}");
    assert_eq!(snap.requests as usize, ds.n_queries());
}

/// The eval harness end to end: sweep → pareto → fixed-recall lookup.
#[test]
fn eval_pipeline_produces_consistent_tables() {
    let sp = synth::spec("demo-64").unwrap();
    let mut ds = synth::generate_counts(sp, 1500, 40, 9);
    ds.compute_ground_truth(10);
    let idx = crinn::anns::glass::GlassIndex::build(
        VectorSet::from_dataset(&ds),
        VariantConfig::glass_baseline(),
        3,
    );
    let sweep = crinn::eval::sweep_index(&idx, &ds, 10, &[16, 48, 128, 256], 0.0);
    let front = sweep.frontier();
    assert!(!front.is_empty());
    for w in front.windows(2) {
        assert!(w[0].recall < w[1].recall && w[0].qps > w[1].qps);
    }
    // Fixed-recall lookups are monotone: QPS@0.8 >= QPS@0.95 when both exist.
    let q80 = crinn::eval::qps_at_recall(&sweep.points, 0.80);
    let q95 = crinn::eval::qps_at_recall(&sweep.points, 0.95);
    if let (Some(a), Some(b)) = (q80, q95) {
        assert!(a >= b * 0.99, "QPS@0.80 {a} < QPS@0.95 {b}");
    }
    let csv = crinn::eval::report::sweeps_to_csv(std::slice::from_ref(&sweep));
    assert_eq!(csv.lines().count(), 1 + sweep.points.len());
}

/// Metric conventions agree between Python oracle and Rust across the
/// bridge: angular dataset distances stay in [0, 2].
#[test]
fn angular_scan_range_via_pjrt() {
    let Some(e) = engine() else { return };
    let sp = synth::spec("glove-25-angular").unwrap();
    let ds = synth::generate_counts(sp, 300, 5, 11);
    let rows = e
        .scan(Metric::Angular, &ds.queries, 5, &ds.base, 300, ds.dim)
        .unwrap();
    for row in rows {
        for d in row {
            assert!((-1e-3..=2.001).contains(&d), "angular distance {d}");
        }
    }
}
