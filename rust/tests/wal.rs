//! Crash-safety and restart properties of the storage tier: snapshot +
//! mutation-log replay must reproduce the live index **bitwise**, and
//! recovery from a torn log tail must drop exactly the un-acked suffix —
//! at *every* byte boundary of the final record, never panicking.
//!
//! The replay-determinism contract under test: the v3 snapshot persists
//! the insert-level RNG state and the free-slot list, so replaying the
//! logged mutations in ack order reassigns exactly the ids the log
//! recorded, and the restored graph is the live graph.

use crinn::anns::glass::GlassIndex;
use crinn::anns::persist::{load_glass, load_glass_mmap, save_glass, save_glass_with_metadata};
use crinn::anns::store::{compact_glass, restore_glass, VectorLog};
use crinn::anns::{AnnIndex, MetadataStore, MutableAnnIndex, VectorSet};
use crinn::dataset::synth;
use crinn::variants::VariantConfig;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("crinn_{}_{name}", std::process::id()))
}

fn demo(n: usize, nq: usize, seed: u64) -> crinn::dataset::Dataset {
    synth::generate_counts(synth::spec("demo-64").unwrap(), n, nq, seed)
}

fn searches(idx: &GlassIndex, ds: &crinn::dataset::Dataset) -> Vec<Vec<(f32, u32)>> {
    (0..ds.n_queries())
        .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 64))
        .collect()
}

#[test]
fn wal_restart_replays_to_bitwise_mirror_heap_and_mmap() {
    let ds = demo(400, 12, 61);
    let mut live = GlassIndex::build(VectorSet::from_dataset(&ds), VariantConfig::crinn_full(), 7);
    let mut live_meta = MetadataStore::new();
    for id in 0..100u32 {
        live_meta.push(Some(&format!("t{}", id % 4)), &["seed"]);
    }
    let snap = tmp("restart.idx");
    let log_path = tmp("restart.wal");
    save_glass_with_metadata(&live, &live_meta, &snap).unwrap();
    let mut log = VectorLog::create(&log_path).unwrap();

    // Mutate the live index past the snapshot, logging in ack order —
    // exactly what Server::start_durable does per mutation.
    for qi in 0..6 {
        let id = live.insert(ds.query_vec(qi)).unwrap();
        log.append_vector(id, ds.query_vec(qi)).unwrap();
        if qi % 2 == 0 {
            live_meta.set_for(id, Some("fresh"), &["replayed"]);
            log.append_metadata(id, Some("fresh"), &["replayed"]).unwrap();
        }
    }
    for id in [3u32, 77, 250] {
        live.delete(id).unwrap();
        log.append_tombstone(id).unwrap();
    }
    drop(log);

    let want = searches(&live, &ds);
    let (live_n, deleted_n) = (live.live_count(), live.deleted_count());
    // Advance the live index by one more (un-logged) probe insert: each
    // restored run must reproduce the same next id and post-probe results
    // — the snapshot + log carried the RNG and free-list state forward.
    let probe = ds.query_vec(7);
    let probe_id = live.insert(probe).unwrap();
    let want_after_probe = searches(&live, &ds);

    for mmap in [false, true] {
        let mut restored = restore_glass(&snap, &log_path, mmap).unwrap();
        assert_eq!(restored.replayed, 12, "mmap={mmap}: 9 mutations + 3 metadata records");
        assert_eq!(restored.index.live_count(), live_n, "mmap={mmap}");
        assert_eq!(restored.index.deleted_count(), deleted_n, "mmap={mmap}");
        assert_eq!(searches(&restored.index, &ds), want, "mmap={mmap}");
        // Replayed metadata is filterable exactly like the live store.
        for id in 0..restored.index.len() as u32 {
            assert_eq!(restored.metadata.tenant(id), live_meta.tenant(id), "mmap={mmap} id {id}");
            assert_eq!(
                restored.metadata.has_tag(id, "replayed"),
                live_meta.has_tag(id, "replayed"),
                "mmap={mmap} id {id}"
            );
        }
        assert_eq!(restored.index.insert(probe).unwrap(), probe_id, "mmap={mmap}");
        assert_eq!(searches(&restored.index, &ds), want_after_probe, "mmap={mmap}");
    }
    std::fs::remove_file(&snap).ok();
    std::fs::remove_file(&log_path).ok();
}

#[test]
fn wal_torn_tail_recovery_at_every_byte_boundary() {
    let ds = demo(300, 8, 62);
    let mut live = GlassIndex::build(VectorSet::from_dataset(&ds), VariantConfig::crinn_full(), 7);
    let snap = tmp("torn.idx");
    let log_path = tmp("torn.wal");
    save_glass(&live, &snap).unwrap();
    let mut log = VectorLog::create(&log_path).unwrap();

    // Four durable mutations, then capture the pre-crash mirror...
    let id = live.insert(ds.query_vec(0)).unwrap();
    log.append_vector(id, ds.query_vec(0)).unwrap();
    live.delete(5).unwrap();
    log.append_tombstone(5).unwrap();
    let id = live.insert(ds.query_vec(1)).unwrap();
    log.append_vector(id, ds.query_vec(1)).unwrap();
    live.delete(17).unwrap();
    log.append_tombstone(17).unwrap();
    let boundary = log.bytes() as usize;
    let mirror_results = searches(&live, &ds);
    let mirror_live = live.live_count();

    // ...then one final insert that the crash tears.
    let id = live.insert(ds.query_vec(2)).unwrap();
    log.append_vector(id, ds.query_vec(2)).unwrap();
    drop(log);
    let full = std::fs::read(&log_path).unwrap();
    assert!(full.len() > boundary + 100, "final record should span many byte boundaries");

    let scratch = tmp("torn_scratch.wal");
    for cut in boundary..full.len() {
        std::fs::write(&scratch, &full[..cut]).unwrap();
        let restored = restore_glass(&snap, &scratch, false)
            .unwrap_or_else(|e| panic!("cut {cut}: recovery must not fail: {e:#}"));
        assert_eq!(restored.replayed, 4, "cut {cut} drops exactly the torn record");
        assert_eq!(restored.index.live_count(), mirror_live, "cut {cut}");
        assert_eq!(
            searches(&restored.index, &ds),
            mirror_results,
            "cut {cut}: replayed state == pre-crash mirror"
        );
        // Recovery physically truncated the torn tail.
        assert_eq!(
            std::fs::metadata(&scratch).unwrap().len(),
            boundary as u64,
            "cut {cut}"
        );
    }
    // The whole file replays to the post-crash state.
    std::fs::write(&scratch, &full).unwrap();
    let restored = restore_glass(&snap, &scratch, false).unwrap();
    assert_eq!(restored.replayed, 5);
    assert_eq!(searches(&restored.index, &ds), searches(&live, &ds));
    std::fs::remove_file(&snap).ok();
    std::fs::remove_file(&log_path).ok();
    std::fs::remove_file(&scratch).ok();
}

#[test]
fn wal_compaction_folds_log_into_snapshot_and_preserves_results() {
    let ds = demo(400, 10, 63);
    let mut live = GlassIndex::build(VectorSet::from_dataset(&ds), VariantConfig::crinn_full(), 7);
    let mut meta = MetadataStore::new();
    for id in 0..400u32 {
        meta.push(Some(&format!("t{}", id % 3)), &[]);
    }
    let snap = tmp("compact.idx");
    let log_path = tmp("compact.wal");
    save_glass_with_metadata(&live, &meta, &snap).unwrap();
    let mut log = VectorLog::create(&log_path).unwrap();
    for id in [2u32, 9, 44, 260] {
        live.delete(id).unwrap();
        log.append_tombstone(id).unwrap();
    }
    let id = live.insert(ds.query_vec(0)).unwrap();
    log.append_vector(id, ds.query_vec(0)).unwrap();
    assert!(log.bytes() > 0);

    let stats = compact_glass(&mut live, &meta, &mut log, &snap).unwrap();
    assert_eq!(stats.dropped, 4, "all four tombstones consolidated away");
    assert!(stats.log_bytes_truncated > 0);
    assert_eq!(stats.log_records_truncated, 5);
    assert_eq!((log.bytes(), log.records()), (0, 0), "log is empty after compaction");

    // The compacted snapshot IS the consolidated live index — bitwise,
    // on both serving tiers — and restart from it replays nothing.
    let want = searches(&live, &ds);
    assert_eq!(searches(&load_glass(&snap).unwrap(), &ds), want);
    assert_eq!(searches(&load_glass_mmap(&snap).unwrap(), &ds), want);
    drop(log);
    let restored = restore_glass(&snap, &log_path, true).unwrap();
    assert_eq!(restored.replayed, 0);
    assert_eq!(restored.index.live_count(), live.live_count());
    assert_eq!(searches(&restored.index, &ds), want);
    std::fs::remove_file(&snap).ok();
    std::fs::remove_file(&log_path).ok();
}

#[test]
fn wal_restore_rejects_mismatched_snapshot_log_pair() {
    // A log whose acked ids cannot come out of this snapshot's
    // free-list/RNG state is detected, not silently re-homed.
    let ds = demo(300, 4, 64);
    let live = GlassIndex::build(VectorSet::from_dataset(&ds), VariantConfig::crinn_full(), 7);
    let snap = tmp("mismatch.idx");
    let log_path = tmp("mismatch.wal");
    save_glass(&live, &snap).unwrap();
    let mut log = VectorLog::create(&log_path).unwrap();
    // A fresh insert into this snapshot gets id 300; claim the ack was 999.
    log.append_vector(999, ds.query_vec(0)).unwrap();
    drop(log);
    let err = format!("{:#}", restore_glass(&snap, &log_path, false).unwrap_err());
    assert!(err.contains("not a matching pair"), "{err}");
    std::fs::remove_file(&snap).ok();
    std::fs::remove_file(&log_path).ok();
}
