//! Property-based tests (hand-rolled proptest substitute): randomized
//! inputs over many seeds, shrunk manually by the failing-seed printout.
//! Focus: coordinator/graph invariants the paper's Critical Requirements
//! demand (determinism, bounded degrees, exactness of substrate pieces).

use crinn::anns::{AnnIndex, VectorSet};
use crinn::dataset::synth;
use crinn::distance::Metric;
use crinn::util::rng::Rng;
use crinn::variants::{decode_action, encode_action, Module, VariantConfig, N_KNOBS};

/// Mini property harness: run `f` for `cases` seeds, reporting the seed on
/// failure (the "shrunk" reproducer).
fn forall(cases: u64, f: impl Fn(u64)) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if let Err(e) = result {
            eprintln!(">>> property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn random_vs(seed: u64, n: usize, dim: usize) -> VectorSet {
    let mut rng = Rng::new(seed);
    let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
    VectorSet::new(data, dim, Metric::L2)
}

/// HNSW graph invariants hold for random shapes, degrees and seeds.
#[test]
fn prop_hnsw_invariants() {
    forall(8, |seed| {
        let mut rng = Rng::new(seed ^ 0xFEED);
        let n = 100 + rng.next_below(400);
        let dim = 2 + rng.next_below(24);
        let m = 4 + rng.next_below(12);
        let knobs = crinn::variants::ConstructionKnobs {
            m,
            ef_construction: 40 + rng.next_below(100),
            num_entry_points: 1 + rng.next_below(9),
            ..Default::default()
        };
        let g = crinn::anns::hnsw::builder::build(random_vs(seed, n, dim), &knobs, seed);
        g.validate().unwrap_or_else(|e| panic!("n={n} dim={dim} m={m}: {e}"));
    });
}

/// Search results are: sorted by distance, distinct, within id range, and
/// deterministic across calls — for every knob combination sampled.
#[test]
fn prop_search_results_wellformed() {
    forall(6, |seed| {
        let mut rng = Rng::new(seed ^ 0xBEEF);
        let n = 300 + rng.next_below(700);
        let dim = 4 + rng.next_below(28);
        let vs = random_vs(seed, n, dim);
        let data = vs.data.clone();
        let action: Vec<f64> = (0..N_KNOBS).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
        let mut cfg = decode_action(&VariantConfig::glass_baseline(), Module::Search, &action);
        let raction: Vec<f64> = (0..N_KNOBS).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
        cfg = decode_action(&cfg, Module::Refinement, &raction);
        let idx = crinn::anns::glass::GlassIndex::build(vs, cfg, seed);
        for t in 0..5 {
            let qi = rng.next_below(n);
            let mut q = data[qi * dim..(qi + 1) * dim].to_vec();
            q[0] += 0.01;
            let k = 1 + rng.next_below(10);
            let ef = k + rng.next_below(100);
            let a = idx.search_with_dists(&q, k, ef);
            let b = idx.search_with_dists(&q, k, ef);
            assert_eq!(a, b, "nondeterministic at trial {t}");
            assert!(a.len() <= k);
            for w in a.windows(2) {
                assert!(
                    crinn::anns::heap::dist_cmp(&w[0], &w[1]) != std::cmp::Ordering::Greater
                );
            }
            let ids: std::collections::HashSet<u32> = a.iter().map(|x| x.1).collect();
            assert_eq!(ids.len(), a.len(), "duplicate ids");
            assert!(a.iter().all(|x| (x.1 as usize) < n));
        }
    });
}

/// Action encode/decode round-trips stay in the box and are idempotent
/// (decode(encode(cfg)) == decode(encode(decode(encode(cfg))))).
#[test]
fn prop_action_roundtrip_stable() {
    forall(20, |seed| {
        let mut rng = Rng::new(seed);
        for module in Module::ALL {
            let a: Vec<f64> = (0..N_KNOBS).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
            let cfg1 = decode_action(&VariantConfig::glass_baseline(), module, &a);
            let e1 = encode_action(&cfg1, module);
            let cfg2 = decode_action(&VariantConfig::glass_baseline(), module, &e1);
            let e2 = encode_action(&cfg2, module);
            for (x, y) in e1.iter().zip(&e2) {
                assert!((x - y).abs() < 1e-6, "module {module:?}: {e1:?} vs {e2:?}");
            }
            assert!(e1.iter().all(|v| (-1.0..=1.0).contains(v)));
        }
    });
}

/// The runtime-dispatched SIMD kernels agree with the portable 8-wide
/// reference within 1e-4 relative tolerance across the full dim set
/// (below/at/above the 8- and 16-lane boundaries and the Table-2 dims).
#[test]
fn prop_simd_matches_portable_kernels() {
    use crinn::distance::{self, simd};
    forall(5, |seed| {
        let mut rng = Rng::new(seed ^ 0x51D);
        for dim in [1usize, 7, 8, 15, 25, 100, 128, 200, 784, 960] {
            let a: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
            let b: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
            let (got, want) = (distance::l2_sq(&a, &b), simd::portable::l2_sq(&a, &b));
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "l2_sq dim={dim}: dispatched {got} vs portable {want}"
            );
            let (got, want) = (distance::dot(&a, &b), simd::portable::dot(&a, &b));
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "dot dim={dim}: dispatched {got} vs portable {want}"
            );
        }
    });
}

/// The runtime-dispatched i8 kernels agree with the portable 32-wide
/// reference EXACTLY (`assert_eq!`, not tolerance — i32 accumulation is
/// order-independent) across dims straddling the 16/32-lane boundaries.
#[test]
fn prop_i8_simd_matches_portable_exactly() {
    use crinn::distance::quant::{dot_i8, l2_sq_i8};
    use crinn::distance::simd::portable_i8;
    forall(5, |seed| {
        let mut rng = Rng::new(seed ^ 0x18D);
        for dim in [1usize, 7, 15, 16, 17, 31, 32, 33, 100, 128, 200, 784, 960] {
            let a: Vec<i8> =
                (0..dim).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> =
                (0..dim).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
            assert_eq!(l2_sq_i8(&a, &b), portable_i8::l2_sq(&a, &b), "l2_sq_i8 dim={dim}");
            assert_eq!(dot_i8(&a, &b), portable_i8::dot(&a, &b), "dot_i8 dim={dim}");
        }
    });
}

/// The SQ8 one-to-many batch path is bitwise identical to per-pair
/// `QuantizedStore::distance` calls, for every metric, over random
/// gathered id lists — the guarantee that lets the GLASS quantized beam
/// and the IVF posting-list scan batch freely.
#[test]
fn prop_quant_batch_matches_per_pair_bitwise() {
    use crinn::distance::quant::QuantizedStore;
    forall(5, |seed| {
        let mut rng = Rng::new(seed ^ 0x5BA7);
        for dim in [1usize, 3, 17, 33, 128] {
            let n = 40 + rng.next_below(80);
            let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
            let store = QuantizedStore::build(&data, dim);
            let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
            let qc = store.encode_query(&q);
            let ids: Vec<u32> = (0..n as u32).filter(|_| rng.next_f64() < 0.5).collect();
            let mut out = Vec::new();
            for metric in [Metric::L2, Metric::Angular, Metric::Ip] {
                store.distance_batch(metric, &qc, &ids, &mut out);
                assert_eq!(out.len(), ids.len());
                for (&id, &d) in ids.iter().zip(&out) {
                    assert_eq!(
                        d,
                        store.distance(metric, &qc, id as usize),
                        "{metric:?} dim={dim} id={id}"
                    );
                }
            }
        }
    });
}

/// The one-to-many batch kernels match the per-pair kernels exactly
/// (bitwise), for every metric, over random gathered id lists.
#[test]
fn prop_batch_kernels_match_per_pair() {
    use crinn::distance::{self, Metric};
    forall(5, |seed| {
        let mut rng = Rng::new(seed ^ 0xBA7C);
        for dim in [1usize, 7, 25, 128, 200] {
            let n = 50 + rng.next_below(100);
            let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
            let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
            let ids: Vec<u32> = (0..n as u32).filter(|_| rng.next_f64() < 0.5).collect();
            let mut out = Vec::new();
            distance::l2_sq_batch(&q, &ids, &data, dim, &mut out);
            assert_eq!(out.len(), ids.len());
            for (&id, &d) in ids.iter().zip(&out) {
                let row = &data[id as usize * dim..(id as usize + 1) * dim];
                assert_eq!(d, distance::l2_sq(&q, row), "l2 batch dim={dim} id={id}");
            }
            distance::dot_batch(&q, &ids, &data, dim, &mut out);
            for (&id, &d) in ids.iter().zip(&out) {
                let row = &data[id as usize * dim..(id as usize + 1) * dim];
                assert_eq!(d, distance::dot(&q, row), "dot batch dim={dim} id={id}");
            }
            for metric in [Metric::L2, Metric::Angular, Metric::Ip] {
                metric.distance_batch(&q, &ids, &data, dim, &mut out);
                for (&id, &d) in ids.iter().zip(&out) {
                    let row = &data[id as usize * dim..(id as usize + 1) * dim];
                    assert_eq!(d, metric.distance(&q, row), "{metric:?} dim={dim} id={id}");
                }
            }
        }
    });
}

// NOTE: the per-index batch==per-query bitwise identity that used to live
// here (`prop_search_batch_matches_per_query_bitwise`) moved into the
// table-driven cross-index suite in `tests/conformance.rs`, which runs it
// together with the recall-floor checks over one shared index table.

/// Parallel query evaluation is bit-identical to sequential: the same
/// index answers the same query set through a forced 4-thread
/// `parallel_map_threads` and a plain 1-thread loop with equal ids (and
/// therefore equal recall), for both HNSW and the full CRINN GLASS config.
#[test]
fn prop_parallel_query_evaluation_bit_identical() {
    use crinn::util::threadpool::parallel_map_threads;
    let sp = synth::spec("demo-64").unwrap();
    let mut ds = synth::generate_counts(sp, 900, 40, 77);
    ds.compute_ground_truth(10);
    let indexes: Vec<Box<dyn AnnIndex>> = vec![
        Box::new(crinn::anns::hnsw::HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &crinn::variants::ConstructionKnobs::default(),
            crinn::variants::SearchKnobs::default(),
            7,
        )),
        Box::new(crinn::anns::glass::GlassIndex::build(
            VectorSet::from_dataset(&ds),
            VariantConfig::crinn_full(),
            7,
        )),
    ];
    let nq = ds.n_queries();
    for idx in &indexes {
        let seq: Vec<Vec<u32>> = (0..nq)
            .map(|qi| idx.search(ds.query_vec(qi), 10, 64))
            .collect();
        let par: Vec<Vec<u32>> =
            parallel_map_threads(nq, 1, 4, |qi| idx.search(ds.query_vec(qi), 10, 64));
        assert_eq!(seq, par, "index {}", idx.name());
    }
}

/// Brute-force top-k is exactly the sorted prefix, any metric/shape.
#[test]
fn prop_bruteforce_exactness() {
    forall(10, |seed| {
        let mut rng = Rng::new(seed ^ 0xACE);
        let n = 20 + rng.next_below(300);
        let dim = 1 + rng.next_below(40);
        let metric = [Metric::L2, Metric::Angular, Metric::Ip][rng.next_below(3)];
        let mut vs = random_vs(seed, n, dim);
        vs.metric = metric;
        if metric == Metric::Angular {
            for row in vs.data.chunks_mut(dim) {
                crinn::distance::normalize(row);
            }
        }
        let data = vs.data.clone();
        let idx = crinn::anns::bruteforce::BruteForceIndex::build(vs);
        let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
        let k = 1 + rng.next_below(n.min(20));
        let got = idx.search(&q, k, 0);
        let mut all: Vec<(f32, u32)> = (0..n)
            .map(|i| (metric.distance(&q, &data[i * dim..(i + 1) * dim]), i as u32))
            .collect();
        all.sort_by(crinn::anns::heap::dist_cmp);
        let want: Vec<u32> = all.iter().take(k).map(|x| x.1).collect();
        assert_eq!(got, want, "n={n} dim={dim} metric={metric:?} k={k}");
    });
}

/// Quantized distance error is bounded and order-preserving "in the
/// large": the exact NN is within the quantized top-10 of 200 points.
#[test]
fn prop_quantization_preserves_neighborhoods() {
    forall(8, |seed| {
        let mut rng = Rng::new(seed ^ 0x5141);
        let n = 200;
        let dim = 8 + rng.next_below(120);
        let vs = random_vs(seed, n, dim);
        let store = crinn::distance::quant::QuantizedStore::build(&vs.data, dim);
        let qi = rng.next_below(n);
        let mut q = vs.vec(qi as u32).to_vec();
        q[0] += 0.05;
        let qc = store.encode_query(&q);
        let mut exact: Vec<(f32, u32)> = (0..n)
            .map(|i| (crinn::distance::l2_sq(&q, vs.vec(i as u32)), i as u32))
            .collect();
        exact.sort_by(crinn::anns::heap::dist_cmp);
        let mut approx: Vec<(f32, u32)> = (0..n)
            .map(|i| (store.distance(Metric::L2, &qc, i), i as u32))
            .collect();
        approx.sort_by(crinn::anns::heap::dist_cmp);
        let top10: Vec<u32> = approx.iter().take(10).map(|x| x.1).collect();
        assert!(
            top10.contains(&exact[0].1),
            "dim={dim}: true NN missing from quantized top-10"
        );
    });
}

/// The reward window AUC is monotone under uniform QPS scaling and
/// invariant to point order.
#[test]
fn prop_reward_auc_properties() {
    use crinn::eval::sweep::CurvePoint;
    forall(15, |seed| {
        let mut rng = Rng::new(seed ^ 0xA0C);
        let n = 3 + rng.next_below(10);
        let mut pts: Vec<CurvePoint> = (0..n)
            .map(|_| CurvePoint {
                ef: 0,
                recall: 0.5 + rng.next_f64() * 0.5,
                qps: 100.0 + rng.next_f64() * 10_000.0,
                mean_latency_s: 0.0,
                p99_latency_s: 0.0,
            })
            .collect();
        let auc = crinn::crinn::reward::window_auc(&pts, 0.85, 0.95);
        assert!(auc >= 0.0);
        // Scale QPS by 2: AUC scales by 2 (when nonzero).
        let scaled: Vec<CurvePoint> = pts
            .iter()
            .map(|p| CurvePoint { qps: p.qps * 2.0, ..p.clone() })
            .collect();
        let auc2 = crinn::crinn::reward::window_auc(&scaled, 0.85, 0.95);
        assert!((auc2 - 2.0 * auc).abs() < 1e-6 * (1.0 + auc), "scaling");
        // Shuffle invariance.
        rng.shuffle(&mut pts);
        let auc3 = crinn::crinn::reward::window_auc(&pts, 0.85, 0.95);
        assert!((auc3 - auc).abs() < 1e-9 * (1.0 + auc), "order dependence");
    });
}

/// Server under random load: every accepted request is answered, with the
/// right k, and counts balance.
#[test]
fn prop_server_accounting() {
    forall(3, |seed| {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 300, 20, seed);
        ds.compute_ground_truth(5);
        let idx: std::sync::Arc<dyn AnnIndex> = std::sync::Arc::new(
            crinn::anns::bruteforce::BruteForceIndex::build(VectorSet::from_dataset(&ds)),
        );
        let server = crinn::coordinator::Server::start(
            idx,
            crinn::coordinator::ServerConfig {
                workers: 2,
                queue_depth: 8,
                ..Default::default()
            },
        );
        let h = server.handle();
        let mut rng = Rng::new(seed);
        let mut accepted = 0u64;
        let mut answered = 0u64;
        let mut pending = Vec::new();
        for _ in 0..100 {
            let qi = rng.next_below(ds.n_queries());
            let k = 1 + rng.next_below(5);
            match h.submit(ds.query_vec(qi).to_vec(), k, 0) {
                Some(rx) => {
                    accepted += 1;
                    pending.push((rx, k));
                }
                None => {}
            }
            if pending.len() > 4 {
                for (rx, k) in pending.drain(..) {
                    let resp = rx.recv().expect("accepted request must be answered");
                    assert_eq!(resp.ids.len(), k);
                    answered += 1;
                }
            }
        }
        for (rx, k) in pending.drain(..) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.ids.len(), k);
            answered += 1;
        }
        let snap = server.shutdown();
        assert_eq!(accepted, answered);
        assert_eq!(snap.requests, accepted);
    });
}
