//! Shared fixtures for the integration-test binaries (`conformance.rs`,
//! `mutation.rs`): the metric-parameterized synthetic datasets, the
//! table of index constructors, and the per-(index, metric) recall
//! floors. Cargo compiles this directory as a module of each test that
//! declares `mod common;`, not as a test target of its own.
//!
//! The floors are **collapse detectors**, not SOTA certificates: they sit
//! well below the recall these builds actually reach (existing unit tests
//! assert the tighter per-index numbers) so that a broken tombstone
//! filter, a mis-repaired graph, or a scrambled batch path fails loudly
//! while normal seed-to-seed variance does not. Ip floors are the
//! loosest: MIPS has no triangle inequality and the graph baselines are
//! only parity-tested there.

#![allow(dead_code)]

use crinn::anns::{AnnIndex, MetadataStore, MutableAnnIndex, VectorSet};
use crinn::dataset::{synth, Dataset};
use crinn::distance::Metric;
use crinn::variants::{ConstructionKnobs, SearchKnobs, VariantConfig};

/// One synthetic dataset per metric (the Ip case reuses the demo manifold
/// under the inner-product convention — there is no Ip preset).
pub fn metric_dataset(metric: Metric, n: usize, nq: usize, seed: u64) -> Dataset {
    let mut ds = match metric {
        Metric::L2 => synth::generate_counts(synth::spec("demo-64").unwrap(), n, nq, seed),
        Metric::Angular => {
            synth::generate_counts(synth::spec("glove-25-angular").unwrap(), n, nq, seed)
        }
        Metric::Ip => {
            let mut ds =
                synth::generate_counts(synth::spec("demo-64").unwrap(), n, nq, seed);
            ds.metric = Metric::Ip;
            ds
        }
    };
    ds.compute_ground_truth(10);
    ds
}

/// One row of the conformance table: how to build the index, which `ef`
/// exercises it (IVF maps ef to nprobe, so it needs a larger budget), and
/// the recall@10 floor per metric.
pub struct IndexCase {
    pub name: &'static str,
    pub ef: usize,
    /// recall@10 floors for (L2, Angular, Ip).
    pub floors: (f64, f64, f64),
    pub build: fn(VectorSet, u64) -> Box<dyn AnnIndex>,
}

pub fn floor_for(case: &IndexCase, metric: Metric) -> f64 {
    match metric {
        Metric::L2 => case.floors.0,
        Metric::Angular => case.floors.1,
        Metric::Ip => case.floors.2,
    }
}

/// The seven index cases as one table — the single place the cross-index
/// conformance loop iterates.
pub fn static_index_cases() -> Vec<IndexCase> {
    vec![
        IndexCase {
            name: "bruteforce",
            ef: 0,
            floors: (0.999, 0.999, 0.999),
            build: |vs, _seed| Box::new(crinn::anns::bruteforce::BruteForceIndex::build(vs)),
        },
        IndexCase {
            name: "hnsw",
            ef: 128,
            floors: (0.85, 0.80, 0.25),
            build: |vs, seed| {
                Box::new(crinn::anns::hnsw::HnswIndex::build(
                    vs,
                    &ConstructionKnobs::default(),
                    SearchKnobs::crinn_discovered(),
                    seed,
                ))
            },
        },
        IndexCase {
            name: "glass",
            ef: 128,
            floors: (0.80, 0.75, 0.25),
            build: |vs, seed| {
                Box::new(crinn::anns::glass::GlassIndex::build(
                    vs,
                    VariantConfig::crinn_full(),
                    seed,
                ))
            },
        },
        IndexCase {
            name: "ivf",
            ef: 256,
            floors: (0.80, 0.70, 0.25),
            build: |vs, seed| {
                Box::new(crinn::anns::ivf::IvfIndex::build(
                    vs,
                    crinn::anns::ivf::IvfParams::default(),
                    seed,
                ))
            },
        },
        IndexCase {
            name: "ivfpq",
            ef: 256,
            floors: (0.75, 0.60, 0.20),
            build: |vs, seed| {
                Box::new(crinn::anns::ivf::IvfIndex::build(
                    vs,
                    crinn::anns::ivf::IvfParams {
                        pq_m: 16,
                        pq_rerank: 8,
                        ..crinn::anns::ivf::IvfParams::default()
                    },
                    seed,
                ))
            },
        },
        IndexCase {
            name: "vamana",
            ef: 128,
            floors: (0.75, 0.65, 0.20),
            build: |vs, seed| {
                Box::new(crinn::anns::vamana::VamanaIndex::build(
                    vs,
                    crinn::anns::vamana::VamanaParams::default(),
                    seed,
                ))
            },
        },
        IndexCase {
            name: "pynndescent",
            ef: 128,
            floors: (0.50, 0.45, 0.10),
            build: |vs, seed| {
                Box::new(crinn::anns::nndescent::NnDescentIndex::build(
                    vs,
                    crinn::anns::nndescent::NnDescentParams::pynndescent(),
                    seed,
                ))
            },
        },
    ]
}

/// One row of the mutation table: the four natively-mutable index types.
/// The `static_floor` is the same L2 collapse floor the conformance table
/// uses — the acceptance bar post-consolidation recall is held to.
pub struct MutableCase {
    pub name: &'static str,
    pub ef: usize,
    pub static_floor: f64,
    pub build: fn(VectorSet, u64) -> Box<dyn MutableAnnIndex>,
}

pub fn mutable_index_cases() -> Vec<MutableCase> {
    vec![
        MutableCase {
            name: "bruteforce",
            ef: 0,
            static_floor: 0.999,
            build: |vs, _seed| Box::new(crinn::anns::bruteforce::BruteForceIndex::build(vs)),
        },
        MutableCase {
            name: "hnsw",
            ef: 128,
            static_floor: 0.85,
            build: |vs, seed| {
                Box::new(crinn::anns::hnsw::HnswIndex::build(
                    vs,
                    &ConstructionKnobs::default(),
                    SearchKnobs::default(),
                    seed,
                ))
            },
        },
        MutableCase {
            name: "glass",
            ef: 128,
            static_floor: 0.80,
            build: |vs, seed| {
                Box::new(crinn::anns::glass::GlassIndex::build(
                    vs,
                    VariantConfig::glass_baseline(),
                    seed,
                ))
            },
        },
        MutableCase {
            name: "ivf",
            ef: 256,
            static_floor: 0.80,
            build: |vs, seed| {
                Box::new(crinn::anns::ivf::IvfIndex::build(
                    vs,
                    crinn::anns::ivf::IvfParams::default(),
                    seed,
                ))
            },
        },
    ]
}

/// Metadata fixture for the filtered-conformance dimension: tenant
/// `t{id%10}` (so any one tenant is ~10% of the base set), tag `"hot"` on
/// ids with `id % 10 != 0` (~90% selectivity), and tag `"rare"` on ids
/// with `id % 100 == 0` (~1% — below the default brute-force fallback
/// threshold at conformance scale, so the exact path is exercised too).
pub fn tenant_tag_metadata(n: usize) -> MetadataStore {
    let mut meta = MetadataStore::new();
    for id in 0..n {
        let tenant = format!("t{}", id % 10);
        let mut tags: Vec<&str> = Vec::new();
        if id % 10 != 0 {
            tags.push("hot");
        }
        if id % 100 == 0 {
            tags.push("rare");
        }
        meta.push(Some(&tenant), &tags);
    }
    meta
}

/// Mean recall@10 of an index over a dataset's query set at one `ef`.
pub fn recall_at(index: &dyn AnnIndex, ds: &Dataset, ef: usize) -> f64 {
    let mut acc = 0.0;
    for qi in 0..ds.n_queries() {
        let found = index.search(ds.query_vec(qi), 10, ef);
        acc += crinn::dataset::gt::recall_at_k(&found, &ds.gt[qi], 10);
    }
    acc / ds.n_queries() as f64
}
