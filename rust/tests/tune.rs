//! The self-tuning contract, from the outside:
//!
//! * seeded determinism — two `tune` pipelines with the same seed produce
//!   **bit-identical** artifact files (the synthetic oracle removes the
//!   only nondeterministic input, wall-clock QPS);
//! * held-out constraint enforcement — the artifact's recall claim is
//!   re-measured here, independently, on the held-out split and must
//!   clear the floor;
//! * encode/decode round-trip identity across the whole tuning space;
//! * hostile-artifact rejection — truncation, corruption, bad version,
//!   and in-range checksums over out-of-range fields all error loudly;
//! * serving-layer plumbing — `ServerConfig::from_tuned` and the metrics
//!   hash gauge.

use crinn::coordinator::ServerConfig;
use crinn::crinn::{
    finalize, split_queries, tune_lagrange, RewardOracle, RewardSpec, SweepOracle,
    SyntheticOracle, TuneOptions,
};
use crinn::dataset::synth;
use crinn::util::rng::Rng;
use crinn::variants::artifact::{payload_checksum, HEADER_BYTES};
use crinn::variants::{IndexFamily, TunedArtifact, TunedConfig, TuningSpace};

fn small_spec() -> RewardSpec {
    RewardSpec {
        ef_grid: vec![16, 32, 64, 128],
        ..Default::default()
    }
}

/// The full synthetic pipeline: search, finalize, serialize.
fn synthetic_pipeline(seed: u64) -> Vec<u8> {
    let space = TuningSpace::for_family(IndexFamily::Glass).unwrap();
    let opts = TuneOptions {
        evals: 12,
        seed,
        recall_floor: 0.2,
        verbose: false,
    };
    let mut train = SyntheticOracle::new(small_spec());
    let res = tune_lagrange(&space, &mut train, &opts).unwrap();
    let mut holdout = SyntheticOracle::new(small_spec());
    let art = finalize(&res, &mut holdout, &opts, "lagrange", "demo-64").unwrap();
    art.to_bytes()
}

#[test]
fn tune_pipeline_is_bitwise_deterministic_per_seed() {
    let a = synthetic_pipeline(17);
    let b = synthetic_pipeline(17);
    assert_eq!(a, b, "same seed must produce identical artifact bytes");
    // A different seed explores differently but still emits a valid file.
    let c = synthetic_pipeline(18);
    assert!(TunedArtifact::from_bytes(&c).is_ok());
    let art_a = TunedArtifact::from_bytes(&a).unwrap();
    assert_eq!(art_a.seed, 17);
    assert_eq!(art_a.method, "lagrange");
}

#[test]
fn tune_enforces_recall_floor_on_held_out_queries() {
    // Real oracle, easy dataset: the artifact's recall claim must hold on
    // queries the search never saw — and we re-measure it here rather
    // than trusting the tuner's own bookkeeping.
    let sp = synth::spec("demo-64").unwrap();
    let mut ds = synth::generate_counts(sp, 1_200, 40, 97);
    ds.compute_ground_truth(10);
    let (train, holdout) = split_queries(&ds);
    let opts = TuneOptions {
        evals: 6,
        seed: 29,
        recall_floor: 0.85,
        verbose: false,
    };
    let space = TuningSpace::for_family(IndexFamily::Glass).unwrap();
    let mut train_oracle = SweepOracle::new(train, small_spec()).with_serving_measurement();
    let res = tune_lagrange(&space, &mut train_oracle, &opts).unwrap();
    let mut hold_oracle =
        SweepOracle::new(holdout.clone(), small_spec()).with_serving_measurement();
    let art = finalize(&res, &mut hold_oracle, &opts, "lagrange", &ds.name).unwrap();
    assert!(
        art.measured_recall >= opts.recall_floor,
        "artifact claims {:.3} < floor",
        art.measured_recall
    );
    assert!(small_spec().ef_grid.contains(&art.config.serving.ef));

    // Independent re-measurement: build the tuned index from scratch and
    // compute recall@10 at the artifact's serving ef on the held-out set.
    let index = crinn::variants::build_index(
        &art.config,
        crinn::anns::VectorSet::from_dataset(&holdout),
        small_spec().seed,
    );
    let k = 10;
    let mut recall_acc = 0.0;
    for qi in 0..holdout.n_queries() {
        let found = index.search(holdout.query_vec(qi), k, art.config.serving.ef);
        recall_acc += crinn::dataset::gt::recall_at_k(&found, &holdout.gt[qi], k);
    }
    let recall = recall_acc / holdout.n_queries() as f64;
    assert!(
        recall >= opts.recall_floor,
        "re-measured held-out recall {recall:.3} under the floor"
    );
}

#[test]
fn tuning_space_roundtrip_identity_everywhere() {
    // decode ∘ encode must be the identity on decoded configs, for every
    // tunable family, across random action vectors: this is what makes
    // "config → action → config" reproducible regardless of which side
    // of the seam produced the point.
    let mut rng = Rng::new(4242);
    for family in IndexFamily::TUNABLE {
        let space = TuningSpace::for_family(family).unwrap();
        for trial in 0..25 {
            let action: Vec<f64> = (0..space.dims())
                .map(|_| rng.range_f64(-1.0, 1.0))
                .collect();
            let c1 = space.decode(&action);
            space.validate(&c1).unwrap_or_else(|e| {
                panic!("{family:?} trial {trial}: decoded config invalid: {e:#}")
            });
            let e1 = space.encode(&c1);
            let c2 = space.decode(&e1);
            assert_eq!(c1, c2, "{family:?} trial {trial}: decode∘encode drifted");
        }
        // The family preset also survives the round trip once snapped.
        let snapped = space.decode(&space.encode(&TunedConfig::for_family(family)));
        assert_eq!(snapped, space.decode(&space.encode(&snapped)));
    }
}

fn sample_artifact() -> TunedArtifact {
    TunedArtifact {
        config: TunedConfig::from_algo_name("crinn").unwrap(),
        dataset: "demo-64".into(),
        method: "lagrange".into(),
        seed: 17,
        evals: 32,
        recall_floor: 0.9,
        measured_recall: 0.94,
    }
}

/// Re-sign a byte-patched artifact so only range validation can reject it.
fn resign(bytes: &mut [u8]) {
    let sum = payload_checksum(&bytes[HEADER_BYTES..]);
    bytes[12..20].copy_from_slice(&sum.to_le_bytes());
}

#[test]
fn tuned_artifact_rejects_hostile_bytes() {
    let good = sample_artifact().to_bytes();
    assert!(TunedArtifact::from_bytes(&good).is_ok());

    // Truncation at every length, including mid-header.
    for cut in 0..good.len() {
        assert!(
            TunedArtifact::from_bytes(&good[..cut]).is_err(),
            "accepted a {cut}-byte prefix"
        );
    }
    // A trailing byte is not "close enough".
    let mut longer = good.clone();
    longer.push(0);
    assert!(TunedArtifact::from_bytes(&longer).is_err());

    // Every single-byte corruption of the payload trips the checksum.
    for off in HEADER_BYTES..good.len() {
        let mut bad = good.clone();
        bad[off] ^= 0x40;
        assert!(
            TunedArtifact::from_bytes(&bad).is_err(),
            "byte {off} flip accepted"
        );
    }

    // Wrong magic / wrong version (outside the checksummed payload).
    let mut bad = good.clone();
    bad[1] = b'!';
    let err = format!("{:#}", TunedArtifact::from_bytes(&bad).unwrap_err());
    assert!(err.contains("not a CRINN"), "{err}");
    let mut bad = good.clone();
    bad[4] = 200;
    let err = format!("{:#}", TunedArtifact::from_bytes(&bad).unwrap_err());
    assert!(err.contains("version"), "{err}");
}

#[test]
fn tuned_artifact_rejects_out_of_range_fields_past_the_checksum() {
    let art = sample_artifact();
    // construction.m sits right after the family tag + label string.
    let m_off = HEADER_BYTES + 4 + 2 + art.config.label.len();
    let mut bad = art.to_bytes();
    bad[m_off..m_off + 4].copy_from_slice(&500_000u32.to_le_bytes());
    resign(&mut bad);
    let err = format!("{:#}", TunedArtifact::from_bytes(&bad).unwrap_err());
    assert!(err.contains("range"), "{err}");

    // A bool byte of 2 is hostile, not truthy.
    let adaptive_ef_off = m_off + 8;
    let mut bad = art.to_bytes();
    bad[adaptive_ef_off] = 2;
    resign(&mut bad);
    let err = format!("{:#}", TunedArtifact::from_bytes(&bad).unwrap_err());
    assert!(err.contains("bool byte 2"), "{err}");

    // recall fields must stay inside [0, 1]: patch measured_recall (the
    // final f64 of the payload) to 7.0 and re-sign.
    let mut bad = art.to_bytes();
    let n = bad.len();
    bad[n - 8..].copy_from_slice(&7.0f64.to_bits().to_le_bytes());
    resign(&mut bad);
    let err = format!("{:#}", TunedArtifact::from_bytes(&bad).unwrap_err());
    assert!(err.contains("outside [0, 1]"), "{err}");
}

#[test]
fn tuned_artifact_file_roundtrip_and_hash_gauge() {
    let art = sample_artifact();
    let path = std::env::temp_dir().join(format!(
        "crinn_{}_tuned_roundtrip.crinn",
        std::process::id()
    ));
    art.save(&path).unwrap();
    let back = TunedArtifact::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(back, art);
    assert_eq!(back.hash(), art.hash());
    assert_ne!(art.hash(), 0);

    let metrics = crinn::coordinator::metrics::Metrics::new();
    metrics.set_tuned_config_hash(art.hash());
    assert_eq!(metrics.snapshot().tuned_config_hash, art.hash());
}

#[test]
fn server_config_from_tuned_maps_serving_knobs() {
    let mut art = sample_artifact();
    art.config.serving.threads = 3;
    art.config.serving.batch = 48;
    let cfg = ServerConfig::from_tuned(&art);
    assert_eq!(cfg.workers, 3);
    assert_eq!(cfg.batch.max_batch, 48);
    assert_eq!(cfg.queue_depth, ServerConfig::default().queue_depth);

    // threads = 0 defers to the ambient CRINN_THREADS/auto sizing.
    art.config.serving.threads = 0;
    let cfg = ServerConfig::from_tuned(&art);
    assert_eq!(cfg.workers, ServerConfig::default().workers);
}

#[test]
fn tune_oracles_share_one_spec_window() {
    // The satellite contract: the 0.85/0.95 window lives in exactly one
    // place and every oracle reports it from there.
    assert_eq!(RewardSpec::DEFAULT_WINDOW, (0.85, 0.95));
    assert_eq!(RewardSpec::default_window(), (0.85, 0.95));
    let spec = RewardSpec::default();
    assert_eq!((spec.recall_lo, spec.recall_hi), RewardSpec::DEFAULT_WINDOW);
    let o = SyntheticOracle::new(small_spec());
    assert_eq!(o.spec().recall_lo, 0.85);
    assert_eq!(o.name(), "synthetic");
}
