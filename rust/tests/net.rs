//! Network serving edge, end to end over real loopback sockets: wire
//! results must be bitwise identical to the in-process submission path,
//! hostile bytes must get error frames (never a panic or an OOM), one
//! over-quota tenant must not starve another, expired deadlines must be
//! dropped and counted, and a graceful shutdown must drain pipelined
//! requests before closing.
#![cfg(unix)]

use crinn::anns::glass::GlassIndex;
use crinn::anns::{AnnIndex, FilterExpr, MetadataStore, VectorSet};
use crinn::coordinator::batcher::BatchPolicy;
use crinn::coordinator::proto::{self, Request, RequestFrame, Response};
use crinn::coordinator::server::{QueryRequest, Reply, SearchRequest};
use crinn::coordinator::{
    AdmissionConfig, Client, NetConfig, NetServer, Server, ServerConfig, SharedMetadata,
    SharedMutableIndex,
};
use crinn::dataset::synth;
use crinn::variants::VariantConfig;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

fn demo(n: usize, nq: usize, seed: u64) -> crinn::dataset::Dataset {
    synth::generate_counts(synth::spec("demo-64").unwrap(), n, nq, seed)
}

/// Mutable GLASS server + metadata (tenants t0..t3 with tag "seed" on the
/// first 100 ids), wrapped in the socket front end on an ephemeral port.
fn start_net(
    ds: &crinn::dataset::Dataset,
    config: ServerConfig,
    net: NetConfig,
) -> NetServer {
    let index = GlassIndex::build(VectorSet::from_dataset(ds), VariantConfig::crinn_full(), 7);
    let mut meta = MetadataStore::new();
    for id in 0..index.len().min(100) {
        meta.push(Some(&format!("t{}", id % 4)), &["seed"]);
    }
    let index: SharedMutableIndex = Arc::new(RwLock::new(Box::new(index)));
    let metadata: SharedMetadata = Arc::new(RwLock::new(meta));
    let server = Server::start_mutable_with_metadata(index, metadata, config);
    NetServer::start(server, "127.0.0.1:0", net).unwrap()
}

fn small_config() -> ServerConfig {
    ServerConfig {
        workers: 2,
        queue_depth: 64,
        batch: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        },
    }
}

/// Pull one whole response frame off a raw socket (tolerates chunked
/// arrival); `None` on EOF before a frame completes.
fn read_raw_response(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Option<(u64, Response)> {
    let mut chunk = [0u8; 4096];
    loop {
        if let Ok(Some((payload, consumed))) = proto::split_frame(buf) {
            let decoded = proto::decode_response(payload).expect("server sent a valid frame");
            buf.drain(..consumed);
            return Some(decoded);
        }
        let n = stream.read(&mut chunk).ok()?;
        if n == 0 {
            return None;
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

fn counter(resp: &Response, name: &str) -> u64 {
    let Response::Metrics { counters } = resp else {
        panic!("expected metrics response, got {resp:?}");
    };
    counters
        .iter()
        .find(|(n, _)| n == name)
        .map(|(_, v)| *v)
        .unwrap_or_else(|| panic!("no counter {name} in {counters:?}"))
}

#[test]
fn loopback_round_trip_is_bitwise_identical_to_in_process() {
    let ds = demo(400, 8, 31);
    let net = start_net(&ds, small_config(), NetConfig::default());
    let addr = net.addr().to_string();
    let handle = net.handle();
    let mut client = Client::connect(&addr, "acme").unwrap();

    let assert_same = |wire: Response, local: crinn::coordinator::QueryResponse| {
        let Response::Search { ids, dists, .. } = wire else {
            panic!("expected search response, got {wire:?}");
        };
        assert_eq!(ids, local.ids);
        let wire_bits: Vec<u32> = dists.iter().map(|d| d.to_bits()).collect();
        let local_bits: Vec<u32> = local.dists.iter().map(|d| d.to_bits()).collect();
        assert_eq!(wire_bits, local_bits, "distances must match bitwise");
    };

    // Plain and filtered searches, wire vs in-process, on the same state.
    for (qi, filter) in [(0, None), (1, Some(FilterExpr::tenant("t1")))] {
        let q = ds.query_vec(qi).to_vec();
        let wire = client.search_filtered(&q, 10, 64, filter.clone()).unwrap();
        let local = handle.query_filtered(q, 10, 64, filter).unwrap();
        assert_same(wire, local);
    }

    // A wire insert is visible to both paths identically...
    let inserted = client
        .insert(ds.query_vec(2), Some("t1"), &["hot"])
        .unwrap();
    let Response::Mutation { result: Ok(new_id), .. } = inserted else {
        panic!("insert failed: {inserted:?}");
    };
    let q = ds.query_vec(2).to_vec();
    let filter = Some(FilterExpr::and(vec![
        FilterExpr::tenant("t1"),
        FilterExpr::tag("hot"),
    ]));
    let wire = client.search_filtered(&q, 5, 64, filter.clone()).unwrap();
    let local = handle.query_filtered(q.clone(), 5, 64, filter.clone()).unwrap();
    assert_eq!(local.ids, vec![new_id], "only the fresh insert has tag hot");
    assert_same(wire, local);

    // ...and so is a wire delete.
    let deleted = client.delete(new_id).unwrap();
    assert!(
        matches!(deleted, Response::Mutation { result: Ok(id), .. } if id == new_id),
        "{deleted:?}"
    );
    let wire = client.search_filtered(&q, 5, 64, filter.clone()).unwrap();
    let local = handle.query_filtered(q, 5, 64, filter).unwrap();
    assert!(local.ids.is_empty(), "deleted point must not match");
    assert_same(wire, local);

    let snap = net.shutdown();
    assert!(snap.connections >= 1);
    assert!(snap.protocol_frames >= 7);
    assert_eq!(snap.protocol_errors, 0);
}

#[test]
fn hostile_frames_get_error_frames_and_close_never_panic() {
    let ds = demo(200, 4, 32);
    let net = start_net(&ds, small_config(), NetConfig::default());
    let addr = net.addr().to_string();

    // (a) garbage magic, (b) oversized length, (c) corrupted checksum.
    let mut valid = proto::encode_request(&RequestFrame {
        request_id: 9,
        tenant: "acme".to_string(),
        deadline_ms: 0,
        body: Request::Metrics,
    });
    valid[proto::FRAME_HEADER] ^= 0xFF; // payload byte flip breaks the crc
    let mut oversized = Vec::new();
    oversized.extend_from_slice(&proto::MAGIC.to_le_bytes());
    oversized.extend_from_slice(&u32::MAX.to_le_bytes());
    oversized.extend_from_slice(&[0u8; 8]);
    for hostile in [b"totally not the protocol".to_vec(), oversized, valid] {
        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.write_all(&hostile).unwrap();
        let mut buf = Vec::new();
        match read_raw_response(&mut raw, &mut buf) {
            Some((_, Response::Error { code, .. })) => assert_eq!(code, proto::ERR_MALFORMED),
            Some((_, other)) => panic!("expected error frame, got {other:?}"),
            None => panic!("connection closed without an error frame"),
        }
        // After the error frame the server closes its end.
        assert!(read_raw_response(&mut raw, &mut buf).is_none());
    }

    // A healthy client on the same server is entirely unaffected.
    let mut client = Client::connect(&addr, "acme").unwrap();
    let resp = client.search(ds.query_vec(0), 5, 32).unwrap();
    assert!(matches!(&resp, Response::Search { ids, .. } if ids.len() == 5), "{resp:?}");
    let metrics = client.metrics().unwrap();
    assert_eq!(counter(&metrics, "protocol_errors"), 3);
    assert!(counter(&metrics, "connections") >= 4);
    drop(client);
    net.shutdown();
}

#[test]
fn over_quota_tenant_gets_overloaded_while_others_complete() {
    let ds = demo(200, 4, 33);
    let net = start_net(
        &ds,
        small_config(),
        NetConfig {
            // One-request burst, effectively no refill: the second request
            // from the same tenant must bounce deterministically.
            admission: AdmissionConfig {
                rate: 0.001,
                burst: 1.0,
                ..Default::default()
            },
            ..NetConfig::default()
        },
    );
    let addr = net.addr().to_string();

    let mut alice = Client::connect(&addr, "alice").unwrap();
    let first = alice.search(ds.query_vec(0), 5, 32).unwrap();
    assert!(matches!(first, Response::Search { .. }), "{first:?}");
    let second = alice.search(ds.query_vec(1), 5, 32).unwrap();
    let Response::Overloaded { retry_after_ms } = second else {
        panic!("expected overloaded, got {second:?}");
    };
    assert!(retry_after_ms > 0, "retry hint should be positive");

    // A different tenant is admitted despite alice's empty bucket.
    let mut bob = Client::connect(&addr, "bob").unwrap();
    let served = bob.search(ds.query_vec(2), 5, 32).unwrap();
    assert!(matches!(served, Response::Search { .. }), "{served:?}");

    // Metrics frames bypass admission (alice is out of tokens here).
    let metrics = alice.metrics().unwrap();
    assert_eq!(counter(&metrics, "tenant.alice.admits"), 1);
    assert_eq!(counter(&metrics, "tenant.alice.rejects"), 1);
    assert_eq!(counter(&metrics, "tenant.bob.admits"), 1);
    drop((alice, bob));
    net.shutdown();
}

#[test]
fn expired_deadline_requests_are_dropped_and_counted() {
    let ds = demo(200, 4, 34);
    // One worker, one-request batches: a plugged worker forces the wire
    // request to wait in the queue past its deadline — deterministically,
    // not by racing a sleep against the batcher.
    let net = start_net(
        &ds,
        ServerConfig {
            workers: 1,
            queue_depth: 64,
            batch: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
            },
        },
        NetConfig::default(),
    );
    let addr = net.addr().to_string();
    let handle = net.handle();

    // Plug: the worker blocks sending into a rendezvous channel nobody
    // reads yet.
    let (plug_tx, plug_rx) = sync_channel(0);
    assert!(handle.submit_request(QueryRequest::Search(SearchRequest {
        query: ds.query_vec(0).to_vec(),
        k: 1,
        ef: 8,
        filter: None,
        submitted: Instant::now(),
        deadline: None,
        reply: Reply::channel(plug_tx),
    })));

    // Release the plug only after the wire request's 30ms budget is long
    // gone.
    let releaser = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(200));
        plug_rx.recv().unwrap()
    });

    let mut client = Client::connect(&addr, "acme").unwrap();
    client.set_deadline_ms(30);
    let resp = client.search(ds.query_vec(1), 5, 32).unwrap();
    let Response::Error { code, message } = resp else {
        panic!("expected dropped-unserved error, got {resp:?}");
    };
    assert_eq!(code, proto::ERR_DROPPED);
    assert!(message.contains("dropped"), "{message}");
    releaser.join().unwrap();

    client.set_deadline_ms(0);
    let metrics = client.metrics().unwrap();
    assert_eq!(counter(&metrics, "deadline_drops"), 1);
    assert_eq!(counter(&metrics, "requests"), 1, "only the plug was served");
    drop(client);
    net.shutdown();
}

#[test]
fn graceful_shutdown_drains_pipelined_requests() {
    let ds = demo(300, 4, 35);
    let net = start_net(&ds, small_config(), NetConfig::default());
    let addr = net.addr().to_string();

    // Pipeline three searches without reading any response, give the
    // event loop a beat to submit them, then drain.
    let mut raw = TcpStream::connect(&addr).unwrap();
    for rid in 1..=3u64 {
        let frame = proto::encode_request(&RequestFrame {
            request_id: rid,
            tenant: "acme".to_string(),
            deadline_ms: 0,
            body: Request::Search {
                k: 5,
                ef: 32,
                filter: None,
                query: ds.query_vec(rid as usize % ds.n_queries()).to_vec(),
            },
        });
        raw.write_all(&frame).unwrap();
    }
    std::thread::sleep(Duration::from_millis(300));
    let snap = net.shutdown();

    // Every pipelined request was answered (not dropped) before close.
    let mut buf = Vec::new();
    let mut seen = Vec::new();
    while let Some((rid, resp)) = read_raw_response(&mut raw, &mut buf) {
        assert!(matches!(resp, Response::Search { .. }), "request {rid}: {resp:?}");
        seen.push(rid);
    }
    seen.sort_unstable();
    assert_eq!(seen, vec![1, 2, 3]);
    assert_eq!(snap.requests, 3);
    assert_eq!(snap.deadline_drops, 0);
}

#[test]
fn reply_abstraction_keeps_channel_and_hook_paths_equivalent() {
    // The same server serves a hook-completed request (the net path) and
    // a channel-completed one (the legacy path) with identical results.
    let ds = demo(200, 4, 36);
    let net = start_net(&ds, small_config(), NetConfig::default());
    let handle = net.handle();

    let legacy = handle.query(ds.query_vec(0).to_vec(), 5, 32).unwrap();
    let (tx, rx) = sync_channel(1);
    assert!(handle.submit_request(QueryRequest::Search(SearchRequest {
        query: ds.query_vec(0).to_vec(),
        k: 5,
        ef: 32,
        filter: None,
        submitted: Instant::now(),
        deadline: None,
        reply: Reply::hook(move |resp| {
            tx.send(resp.expect("served, not dropped")).unwrap();
        }),
    })));
    let hooked = rx.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(hooked.ids, legacy.ids);
    let hook_bits: Vec<u32> = hooked.dists.iter().map(|d| d.to_bits()).collect();
    let legacy_bits: Vec<u32> = legacy.dists.iter().map(|d| d.to_bits()).collect();
    assert_eq!(hook_bits, legacy_bits);
    net.shutdown();
}

#[test]
fn serve_cli_listens_and_drains_on_stdin_close() {
    use std::io::{BufRead, BufReader};
    use std::process::{Command, Stdio};

    let mut child = Command::new(env!("CARGO_BIN_EXE_crinn"))
        .args([
            "serve",
            "--listen",
            "127.0.0.1:0",
            "--dataset",
            "demo-64",
            "--n",
            "1000",
            "--queries",
            "5",
            "--shards",
            "1",
        ])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn crinn serve --listen");

    let stdout = child.stdout.take().unwrap();
    let mut lines = BufReader::new(stdout).lines();
    let addr = loop {
        let line = lines
            .next()
            .expect("stdout closed before the listening line")
            .unwrap();
        if let Some(rest) = line.strip_prefix("listening on ") {
            break rest.to_string();
        }
    };

    let mut client = Client::connect(&addr, "cli-test").unwrap();
    let q = vec![0.1f32; 64];
    let resp = client.search(&q, 5, 32).unwrap();
    assert!(matches!(&resp, Response::Search { ids, .. } if ids.len() == 5), "{resp:?}");
    drop(client);

    // Closing stdin is the stop signal; the server drains and exits 0.
    drop(child.stdin.take());
    let status = child.wait().expect("wait for crinn serve");
    assert!(status.success(), "serve exited with {status:?}");
    let summary: Vec<String> = lines.map_while(|l| l.ok()).collect();
    assert!(
        summary.iter().any(|l| l.starts_with("served ")),
        "missing summary in {summary:?}"
    );
}
