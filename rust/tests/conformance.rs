//! Cross-index conformance suite: ONE table-driven harness that runs
//! every index type over synthetic L2 / Angular / Ip datasets and holds
//! each to the shared contract of the `AnnIndex` trait:
//!
//! 1. **Recall floor** — mean recall@10 against exact ground truth
//!    recomputed here through `gt::topk_pairs_for_query` must clear the
//!    per-(index, metric) collapse floor in `tests/common/mod.rs`.
//! 2. **Batch identity** — `search_batch` is bitwise identical
//!    (distances AND ids) to per-query `search_with_dists`, across batch
//!    shapes: the whole query set as one batch, chunked batches with a
//!    trailing partial chunk, and singleton batches.
//! 3. **Projection** — ids-only `search` is exactly the id projection of
//!    `search_with_dists`.
//! 4. **Well-formedness** — results sorted by `(dist, id)`, distinct,
//!    in id range.
//! 5. **Filtered recall** — `search_filtered_with_dists` holds recall@10
//!    floors against *filtered* ground truth at ~90% / ~10% / ~1%
//!    selectivity, surfaces only matching ids, keeps filtered batch ==
//!    per-query bitwise, and is bitwise identical to the unfiltered
//!    entry points when `filter=None`.
//!
//! This replaces the per-index ad-hoc copies that used to live in
//! `properties.rs` (`prop_search_batch_matches_per_query_bitwise`) with a
//! single loop over `common::static_index_cases()` — adding an index type
//! means adding one table row, not another hand-rolled test.

mod common;

use crinn::anns::VectorSet;
use crinn::distance::Metric;

fn conformance_for_metric(metric: Metric, seed: u64) {
    let ds = common::metric_dataset(metric, 1200, 24, seed);
    // Ground truth recomputed through the public scan entry point the
    // issue pins: gt::topk_pairs_for_query (ds.gt comes from the same
    // kernel via brute_force_topk; this keeps the oracle explicit).
    let (mut idbuf, mut dbuf) = (Vec::new(), Vec::new());
    let gt: Vec<Vec<u32>> = (0..ds.n_queries())
        .map(|qi| {
            crinn::dataset::gt::topk_pairs_for_query(
                &ds.base,
                ds.query_vec(qi),
                ds.dim,
                ds.metric,
                10,
                &mut idbuf,
                &mut dbuf,
            )
            .into_iter()
            .map(|(_, i)| i)
            .collect()
        })
        .collect();

    let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();
    for case in common::static_index_cases() {
        let idx = (case.build)(VectorSet::from_dataset(&ds), 7);
        assert_eq!(idx.len(), ds.n_base(), "{} {metric:?}", case.name);

        // --- 1. Recall floor vs the explicit oracle.
        let mut acc = 0.0;
        for (qi, q) in queries.iter().enumerate() {
            let found = idx.search(q, 10, case.ef);
            acc += crinn::dataset::gt::recall_at_k(&found, &gt[qi], 10);
        }
        let recall = acc / queries.len() as f64;
        let floor = common::floor_for(&case, metric);
        assert!(
            recall >= floor,
            "{} {metric:?}: recall@10 {recall:.3} below floor {floor}",
            case.name
        );

        // --- 2–4. Batch identity, projection, well-formedness.
        for (k, ef) in [(10usize, case.ef.max(64)), (5, case.ef.max(16).min(64))] {
            let per_query: Vec<Vec<(f32, u32)>> = queries
                .iter()
                .map(|q| idx.search_with_dists(q, k, ef))
                .collect();
            // Whole set as one batch.
            assert_eq!(
                idx.search_batch(&queries, k, ef),
                per_query,
                "{} {metric:?} k={k} ef={ef} (single batch)",
                case.name
            );
            // Chunked batches, incl. a trailing partial chunk + singletons.
            for bs in [1usize, 7] {
                let chunked: Vec<Vec<(f32, u32)>> = queries
                    .chunks(bs)
                    .flat_map(|chunk| idx.search_batch(chunk, k, ef))
                    .collect();
                assert_eq!(
                    chunked, per_query,
                    "{} {metric:?} k={k} ef={ef} bs={bs}",
                    case.name
                );
            }
            for (qi, q) in queries.iter().enumerate() {
                // Projection.
                let ids: Vec<u32> = per_query[qi].iter().map(|&(_, i)| i).collect();
                assert_eq!(idx.search(q, k, ef), ids, "{} projection", case.name);
                // Well-formed: sorted, distinct, in range.
                assert!(per_query[qi].len() <= k);
                for w in per_query[qi].windows(2) {
                    assert!(
                        crinn::anns::heap::dist_cmp(&w[0], &w[1]) != std::cmp::Ordering::Greater,
                        "{} {metric:?} unsorted",
                        case.name
                    );
                }
                let set: std::collections::HashSet<u32> = ids.iter().copied().collect();
                assert_eq!(set.len(), ids.len(), "{} duplicate ids", case.name);
                assert!(ids.iter().all(|&i| (i as usize) < ds.n_base()));
            }
        }
        // Empty batch: well-formed, no output.
        assert!(idx.search_batch(&[], 10, 64).is_empty(), "{}", case.name);
    }
}

/// Filtered-recall dimension: every index type is held to recall@10
/// floors against *filtered* ground truth at three selectivity tiers,
/// with the filter bitsets compiled from `FilterExpr`s through a
/// `MetadataStore` — the same pipeline the coordinator uses:
///
/// * `sel90` (tag "hot", ~90% of ids) — beam path, floors track the
///   unfiltered collapse floors;
/// * `sel10` (tenant "t3", ~10%) — beam path under a sparse filter,
///   loosened floors (fewer admissible candidates per beam);
/// * `sel1` (tag "rare", ~1%, popcount 12 at this scale) — below the
///   default fallback threshold, so every index answers via filtered
///   brute force and recall must be exact.
///
/// Also holds the contract invariants under filters: only matching ids
/// surface, filtered batch == filtered per-query bitwise, and
/// `filter=None` is bitwise identical to the unfiltered entry points.
fn filtered_conformance_for_metric(metric: Metric, seed: u64) {
    let ds = common::metric_dataset(metric, 1200, 24, seed);
    let n = ds.n_base();
    let meta = common::tenant_tag_metadata(n);
    let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();
    let tiers: Vec<(&str, crinn::anns::FilterExpr)> = vec![
        ("sel90", crinn::anns::FilterExpr::tag("hot")),
        ("sel10", crinn::anns::FilterExpr::tenant("t3")),
        ("sel1", crinn::anns::FilterExpr::tag("rare")),
    ];

    for case in common::static_index_cases() {
        let idx = (case.build)(VectorSet::from_dataset(&ds), 7);

        for (tier, expr) in &tiers {
            let filter = meta.compile(expr, n);
            // Filtered ground truth through the oracle the filtered
            // brute-force path is held identical to.
            let (mut idbuf, mut dbuf) = (Vec::new(), Vec::new());
            let gt: Vec<Vec<u32>> = queries
                .iter()
                .map(|q| {
                    crinn::dataset::gt::topk_pairs_for_query_filtered(
                        &ds.base,
                        q,
                        ds.dim,
                        ds.metric,
                        10,
                        &mut idbuf,
                        &mut dbuf,
                        |i| filter.matches(i),
                    )
                    .into_iter()
                    .map(|(_, i)| i)
                    .collect()
                })
                .collect();

            let per_query: Vec<Vec<(f32, u32)>> = queries
                .iter()
                .map(|q| idx.search_filtered_with_dists(q, 10, case.ef, Some(&filter)))
                .collect();

            let mut acc = 0.0;
            for (qi, res) in per_query.iter().enumerate() {
                for &(_, id) in res {
                    assert!(
                        filter.matches(id),
                        "{} {metric:?} {tier}: non-matching id {id} surfaced",
                        case.name
                    );
                }
                let ids: Vec<u32> = res.iter().map(|&(_, i)| i).collect();
                acc += crinn::dataset::gt::recall_at_k(&ids, &gt[qi], 10);
            }
            let recall = acc / queries.len() as f64;
            let floor = match *tier {
                // ~90% selectivity barely changes the problem; the
                // unfiltered collapse floors apply (eased a touch for
                // the GT shift from dropping every 10th id).
                "sel90" => (common::floor_for(&case, metric) - 0.05).max(0.05),
                // Sparse beam tier: only ~1 in 10 visited nodes is
                // admissible, so the floors are collapse detectors only.
                // Brute force stays exact at any selectivity.
                "sel10" if case.name == "bruteforce" => 0.999,
                "sel10" => (common::floor_for(&case, metric) - 0.25).max(0.10),
                // Below the fallback threshold: exact by construction.
                _ => 0.999,
            };
            assert!(
                recall >= floor,
                "{} {metric:?} {tier}: filtered recall@10 {recall:.3} below floor {floor}",
                case.name
            );

            // Filtered batch == filtered per-query, bitwise.
            assert_eq!(
                idx.search_filtered_batch(&queries, 10, case.ef, Some(&filter)),
                per_query,
                "{} {metric:?} {tier}: filtered batch != per-query",
                case.name
            );
        }

        // filter=None is the unfiltered path, bitwise.
        let ef = case.ef.max(64);
        for q in &queries {
            assert_eq!(
                idx.search_filtered_with_dists(q, 10, ef, None),
                idx.search_with_dists(q, 10, ef),
                "{} {metric:?}: filter=None diverges from search_with_dists",
                case.name
            );
        }
        assert_eq!(
            idx.search_filtered_batch(&queries, 10, ef, None),
            idx.search_batch(&queries, 10, ef),
            "{} {metric:?}: filter=None diverges from search_batch",
            case.name
        );
    }
}

/// Disk-serving dimension: a GLASS snapshot loaded back onto the heap
/// and one served zero-copy out of an mmapped section container must
/// both be **bitwise identical** to the in-memory index they were saved
/// from — distances AND ids, per-query and batched, filtered and
/// unfiltered. Storage tier (heap vs page cache) must be invisible to
/// search results.
fn mmap_conformance_for_metric(metric: Metric, seed: u64) {
    let ds = common::metric_dataset(metric, 1000, 20, seed);
    let idx = crinn::anns::glass::GlassIndex::build(
        VectorSet::from_dataset(&ds),
        crinn::variants::VariantConfig::crinn_full(),
        7,
    );
    let path = std::env::temp_dir().join(format!(
        "crinn_{}_conformance_mmap_{metric:?}.idx",
        std::process::id()
    ));
    crinn::anns::persist::save_glass(&idx, &path).unwrap();
    let heap = crinn::anns::persist::load_glass(&path).unwrap();
    let mapped = crinn::anns::persist::load_glass_mmap(&path).unwrap();
    assert!(mapped.graph.layer0.is_mapped(), "{metric:?}: adjacency not region-served");

    use crinn::anns::AnnIndex;
    let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();
    let n = ds.n_base();
    let filter = crinn::anns::FilterBitset::from_predicate(n, |id| id % 3 != 0);
    for (k, ef) in [(10usize, 128), (5, 32)] {
        for q in &queries {
            let want = idx.search_with_dists(q, k, ef);
            assert_eq!(heap.search_with_dists(q, k, ef), want, "{metric:?} heap k={k}");
            assert_eq!(mapped.search_with_dists(q, k, ef), want, "{metric:?} mmap k={k}");
            let fwant = idx.search_filtered_with_dists(q, k, ef, Some(&filter));
            assert_eq!(
                mapped.search_filtered_with_dists(q, k, ef, Some(&filter)),
                fwant,
                "{metric:?} mmap filtered k={k}"
            );
        }
        let want = idx.search_batch(&queries, k, ef);
        assert_eq!(heap.search_batch(&queries, k, ef), want, "{metric:?} heap batch");
        assert_eq!(mapped.search_batch(&queries, k, ef), want, "{metric:?} mmap batch");
    }
    std::fs::remove_file(&path).ok();
}

/// IVF-PQ acceptance: the trained PQ store must hold at most 1/8 the
/// vector bytes of the f32 base set (4-bit codes + codebooks) while the
/// `ivfpq` table row above clears its recall floors with exact rerank.
#[test]
fn conformance_ivfpq_pq_store_stays_under_one_eighth_of_f32() {
    let ds = common::metric_dataset(Metric::L2, 1200, 8, 84);
    let idx = crinn::anns::ivf::IvfIndex::build(
        VectorSet::from_dataset(&ds),
        crinn::anns::ivf::IvfParams {
            pq_m: 16,
            pq_rerank: 8,
            ..crinn::anns::ivf::IvfParams::default()
        },
        7,
    );
    let pq = idx.pq_store().expect("ivfpq build trains a PqStore");
    let f32_bytes = ds.n_base() * ds.dim * 4;
    assert!(
        pq.bytes() * 8 <= f32_bytes,
        "pq store {} bytes exceeds 1/8 of the {f32_bytes}-byte f32 set",
        pq.bytes()
    );
}

#[test]
fn conformance_batch_identity_and_recall_l2() {
    conformance_for_metric(Metric::L2, 81);
}

#[test]
fn conformance_batch_identity_and_recall_angular() {
    conformance_for_metric(Metric::Angular, 82);
}

#[test]
fn conformance_batch_identity_and_recall_ip() {
    conformance_for_metric(Metric::Ip, 83);
}

#[test]
fn filtered_conformance_recall_l2() {
    filtered_conformance_for_metric(Metric::L2, 81);
}

#[test]
fn filtered_conformance_recall_angular() {
    filtered_conformance_for_metric(Metric::Angular, 82);
}

#[test]
fn filtered_conformance_recall_ip() {
    filtered_conformance_for_metric(Metric::Ip, 83);
}

#[test]
fn conformance_mmap_serving_bitwise_identical_l2() {
    mmap_conformance_for_metric(Metric::L2, 81);
}

#[test]
fn conformance_mmap_serving_bitwise_identical_angular() {
    mmap_conformance_for_metric(Metric::Angular, 82);
}

#[test]
fn conformance_mmap_serving_bitwise_identical_ip() {
    mmap_conformance_for_metric(Metric::Ip, 83);
}
