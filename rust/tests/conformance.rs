//! Cross-index conformance suite: ONE table-driven harness that runs
//! every index type over synthetic L2 / Angular / Ip datasets and holds
//! each to the shared contract of the `AnnIndex` trait:
//!
//! 1. **Recall floor** — mean recall@10 against exact ground truth
//!    recomputed here through `gt::topk_pairs_for_query` must clear the
//!    per-(index, metric) collapse floor in `tests/common/mod.rs`.
//! 2. **Batch identity** — `search_batch` is bitwise identical
//!    (distances AND ids) to per-query `search_with_dists`, across batch
//!    shapes: the whole query set as one batch, chunked batches with a
//!    trailing partial chunk, and singleton batches.
//! 3. **Projection** — ids-only `search` is exactly the id projection of
//!    `search_with_dists`.
//! 4. **Well-formedness** — results sorted by `(dist, id)`, distinct,
//!    in id range.
//!
//! This replaces the per-index ad-hoc copies that used to live in
//! `properties.rs` (`prop_search_batch_matches_per_query_bitwise`) with a
//! single loop over `common::static_index_cases()` — adding an index type
//! means adding one table row, not another hand-rolled test.

mod common;

use crinn::anns::VectorSet;
use crinn::distance::Metric;

fn conformance_for_metric(metric: Metric, seed: u64) {
    let ds = common::metric_dataset(metric, 1200, 24, seed);
    // Ground truth recomputed through the public scan entry point the
    // issue pins: gt::topk_pairs_for_query (ds.gt comes from the same
    // kernel via brute_force_topk; this keeps the oracle explicit).
    let (mut idbuf, mut dbuf) = (Vec::new(), Vec::new());
    let gt: Vec<Vec<u32>> = (0..ds.n_queries())
        .map(|qi| {
            crinn::dataset::gt::topk_pairs_for_query(
                &ds.base,
                ds.query_vec(qi),
                ds.dim,
                ds.metric,
                10,
                &mut idbuf,
                &mut dbuf,
            )
            .into_iter()
            .map(|(_, i)| i)
            .collect()
        })
        .collect();

    let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();
    for case in common::static_index_cases() {
        let idx = (case.build)(VectorSet::from_dataset(&ds), 7);
        assert_eq!(idx.len(), ds.n_base(), "{} {metric:?}", case.name);

        // --- 1. Recall floor vs the explicit oracle.
        let mut acc = 0.0;
        for (qi, q) in queries.iter().enumerate() {
            let found = idx.search(q, 10, case.ef);
            acc += crinn::dataset::gt::recall_at_k(&found, &gt[qi], 10);
        }
        let recall = acc / queries.len() as f64;
        let floor = common::floor_for(&case, metric);
        assert!(
            recall >= floor,
            "{} {metric:?}: recall@10 {recall:.3} below floor {floor}",
            case.name
        );

        // --- 2–4. Batch identity, projection, well-formedness.
        for (k, ef) in [(10usize, case.ef.max(64)), (5, case.ef.max(16).min(64))] {
            let per_query: Vec<Vec<(f32, u32)>> = queries
                .iter()
                .map(|q| idx.search_with_dists(q, k, ef))
                .collect();
            // Whole set as one batch.
            assert_eq!(
                idx.search_batch(&queries, k, ef),
                per_query,
                "{} {metric:?} k={k} ef={ef} (single batch)",
                case.name
            );
            // Chunked batches, incl. a trailing partial chunk + singletons.
            for bs in [1usize, 7] {
                let chunked: Vec<Vec<(f32, u32)>> = queries
                    .chunks(bs)
                    .flat_map(|chunk| idx.search_batch(chunk, k, ef))
                    .collect();
                assert_eq!(
                    chunked, per_query,
                    "{} {metric:?} k={k} ef={ef} bs={bs}",
                    case.name
                );
            }
            for (qi, q) in queries.iter().enumerate() {
                // Projection.
                let ids: Vec<u32> = per_query[qi].iter().map(|&(_, i)| i).collect();
                assert_eq!(idx.search(q, k, ef), ids, "{} projection", case.name);
                // Well-formed: sorted, distinct, in range.
                assert!(per_query[qi].len() <= k);
                for w in per_query[qi].windows(2) {
                    assert!(
                        crinn::anns::heap::dist_cmp(&w[0], &w[1]) != std::cmp::Ordering::Greater,
                        "{} {metric:?} unsorted",
                        case.name
                    );
                }
                let set: std::collections::HashSet<u32> = ids.iter().copied().collect();
                assert_eq!(set.len(), ids.len(), "{} duplicate ids", case.name);
                assert!(ids.iter().all(|&i| (i as usize) < ds.n_base()));
            }
        }
        // Empty batch: well-formed, no output.
        assert!(idx.search_batch(&[], 10, 64).is_empty(), "{}", case.name);
    }
}

#[test]
fn conformance_batch_identity_and_recall_l2() {
    conformance_for_metric(Metric::L2, 81);
}

#[test]
fn conformance_batch_identity_and_recall_angular() {
    conformance_for_metric(Metric::Angular, 82);
}

#[test]
fn conformance_batch_identity_and_recall_ip() {
    conformance_for_metric(Metric::Ip, 83);
}
