//! Build-wiring smoke tests: the `crinn` binary links against the library,
//! prints its usage text, and the engine-free subcommands run. Uses the
//! `CARGO_BIN_EXE_<name>` env Cargo sets for integration tests, which also
//! forces the bin target to build under `cargo test`.

use std::process::Command;

fn crinn_cmd() -> Command {
    Command::new(env!("CARGO_BIN_EXE_crinn"))
}

#[test]
fn no_args_prints_usage_and_exits_2() {
    let out = crinn_cmd().output().expect("run crinn");
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("usage: crinn <datasets|sweep|train|tune|serve|prompt|compact>"),
        "stderr was: {stderr}"
    );
    // Every subcommand README.md §Quickstart documents is listed.
    for sub in ["datasets", "sweep", "train", "tune", "serve", "prompt", "compact"] {
        assert!(stderr.contains(sub), "usage is missing `{sub}`");
    }
}

#[test]
fn unknown_subcommand_prints_usage_and_exits_2() {
    let out = crinn_cmd().arg("frobnicate").output().expect("run crinn");
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("usage: crinn"), "stderr was: {stderr}");
}

#[test]
fn prompt_subcommand_renders_table1_prompt() {
    // `crinn prompt` needs no dataset, no artifacts, and no engine — the
    // cheapest end-to-end path through the binary.
    let out = crinn_cmd()
        .args(["prompt", "--module", "search"])
        .output()
        .expect("run crinn prompt");
    assert_eq!(out.status.code(), Some(0), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for section in [
        "## Task Description",
        "## Previous Implementations with Speed",
        "## Generation Protocol",
        "## Critical Requirements",
    ] {
        assert!(stdout.contains(section), "prompt missing {section}");
    }
}

#[test]
fn sweep_results_identical_across_thread_counts() {
    // The acceptance contract for the parallel sweep path: `crinn sweep`
    // must emit bit-identical ef/recall rows under CRINN_THREADS=1 (the
    // sequential ann-benchmarks protocol) and a threaded run. Subprocess
    // env is per-run, so this is race-free unlike in-process set_var.
    let run = |threads: &str| -> Vec<(String, String)> {
        let out = crinn_cmd()
            .args([
                "sweep",
                "--dataset",
                "demo-64",
                "--algo",
                "hnsw",
                "--n",
                "600",
                "--queries",
                "30",
                "--ef",
                "16,64",
            ])
            .env("CRINN_THREADS", threads)
            .output()
            .expect("run crinn sweep");
        assert_eq!(
            out.status.code(),
            Some(0),
            "stderr: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8_lossy(&out.stdout);
        // Keep only the deterministic columns (ef, recall) — qps and the
        // latency percentiles are timing-dependent.
        stdout
            .lines()
            .skip(1) // CSV header
            .map(|l| {
                let mut f = l.split(',');
                (
                    f.next().expect("ef column").to_string(),
                    f.next().expect("recall column").to_string(),
                )
            })
            .collect()
    };
    let sequential = run("1");
    let threaded = run("4");
    assert_eq!(sequential.len(), 2, "expected one row per ef value");
    assert_eq!(sequential, threaded);
}

#[test]
fn tune_then_serve_tuned_roundtrip() {
    // The self-tuning loop end-to-end through the binary, engine-free:
    // `crinn tune --oracle synthetic --method lagrange` writes an
    // artifact, `crinn serve --tuned` loads it and serves with its knobs.
    let out_path = std::env::temp_dir().join(format!(
        "crinn_{}_tune_smoke.crinn",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&out_path);
    let tune = crinn_cmd()
        .args([
            "tune",
            "--dataset",
            "demo-64",
            "--n",
            "400",
            "--queries",
            "20",
            "--evals",
            "6",
            "--floor",
            "0.2",
            "--oracle",
            "synthetic",
            "--out",
            out_path.to_str().unwrap(),
        ])
        .env("CRINN_THREADS", "2")
        .output()
        .expect("run crinn tune");
    assert_eq!(
        tune.status.code(),
        Some(0),
        "stderr: {}",
        String::from_utf8_lossy(&tune.stderr)
    );
    let stdout = String::from_utf8_lossy(&tune.stdout);
    assert!(stdout.contains("held-out recall@"), "stdout: {stdout}");
    let serve = crinn_cmd()
        .args([
            "serve",
            "--dataset",
            "demo-64",
            "--n",
            "400",
            "--queries",
            "20",
            "--requests",
            "40",
            "--tuned",
            out_path.to_str().unwrap(),
        ])
        .env("CRINN_THREADS", "2")
        .output()
        .expect("run crinn serve --tuned");
    assert_eq!(
        serve.status.code(),
        Some(0),
        "stderr: {}",
        String::from_utf8_lossy(&serve.stderr)
    );
    let serve_err = String::from_utf8_lossy(&serve.stderr);
    assert!(serve_err.contains("tuned artifact"), "stderr: {serve_err}");
    let _ = std::fs::remove_file(&out_path);
}

#[test]
fn serve_rejects_corrupt_tuned_artifact() {
    // A flipped byte must fail loudly (checksum), never panic or serve.
    let path = std::env::temp_dir().join(format!(
        "crinn_{}_tuned_corrupt.crinn",
        std::process::id()
    ));
    std::fs::write(&path, b"CRTCgarbage-that-is-not-an-artifact").unwrap();
    let out = crinn_cmd()
        .args([
            "serve", "--dataset", "demo-64", "--n", "300", "--queries", "10", "--tuned",
            path.to_str().unwrap(),
        ])
        .output()
        .expect("run crinn serve --tuned");
    assert_eq!(out.status.code(), Some(1));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("tuned-config"), "stderr: {stderr}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn compact_without_snapshot_exits_2() {
    let out = crinn_cmd().arg("compact").output().expect("run crinn compact");
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--snapshot"), "stderr was: {stderr}");
}

#[test]
fn prompt_rejects_unknown_module() {
    let out = crinn_cmd()
        .args(["prompt", "--module", "bogus"])
        .output()
        .expect("run crinn prompt");
    assert_eq!(out.status.code(), Some(2));
}
