//! Build-wiring smoke tests: the `crinn` binary links against the library,
//! prints its usage text, and the engine-free subcommands run. Uses the
//! `CARGO_BIN_EXE_<name>` env Cargo sets for integration tests, which also
//! forces the bin target to build under `cargo test`.

use std::process::Command;

fn crinn_cmd() -> Command {
    Command::new(env!("CARGO_BIN_EXE_crinn"))
}

#[test]
fn no_args_prints_usage_and_exits_2() {
    let out = crinn_cmd().output().expect("run crinn");
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("usage: crinn <datasets|sweep|train|serve|prompt|compact>"),
        "stderr was: {stderr}"
    );
    // Every subcommand README.md §Quickstart documents is listed.
    for sub in ["datasets", "sweep", "train", "serve", "prompt", "compact"] {
        assert!(stderr.contains(sub), "usage is missing `{sub}`");
    }
}

#[test]
fn unknown_subcommand_prints_usage_and_exits_2() {
    let out = crinn_cmd().arg("frobnicate").output().expect("run crinn");
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("usage: crinn"), "stderr was: {stderr}");
}

#[test]
fn prompt_subcommand_renders_table1_prompt() {
    // `crinn prompt` needs no dataset, no artifacts, and no engine — the
    // cheapest end-to-end path through the binary.
    let out = crinn_cmd()
        .args(["prompt", "--module", "search"])
        .output()
        .expect("run crinn prompt");
    assert_eq!(out.status.code(), Some(0), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for section in [
        "## Task Description",
        "## Previous Implementations with Speed",
        "## Generation Protocol",
        "## Critical Requirements",
    ] {
        assert!(stdout.contains(section), "prompt missing {section}");
    }
}

#[test]
fn sweep_results_identical_across_thread_counts() {
    // The acceptance contract for the parallel sweep path: `crinn sweep`
    // must emit bit-identical ef/recall rows under CRINN_THREADS=1 (the
    // sequential ann-benchmarks protocol) and a threaded run. Subprocess
    // env is per-run, so this is race-free unlike in-process set_var.
    let run = |threads: &str| -> Vec<(String, String)> {
        let out = crinn_cmd()
            .args([
                "sweep",
                "--dataset",
                "demo-64",
                "--algo",
                "hnsw",
                "--n",
                "600",
                "--queries",
                "30",
                "--ef",
                "16,64",
            ])
            .env("CRINN_THREADS", threads)
            .output()
            .expect("run crinn sweep");
        assert_eq!(
            out.status.code(),
            Some(0),
            "stderr: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8_lossy(&out.stdout);
        // Keep only the deterministic columns (ef, recall) — qps and the
        // latency percentiles are timing-dependent.
        stdout
            .lines()
            .skip(1) // CSV header
            .map(|l| {
                let mut f = l.split(',');
                (
                    f.next().expect("ef column").to_string(),
                    f.next().expect("recall column").to_string(),
                )
            })
            .collect()
    };
    let sequential = run("1");
    let threaded = run("4");
    assert_eq!(sequential.len(), 2, "expected one row per ef value");
    assert_eq!(sequential, threaded);
}

#[test]
fn compact_without_snapshot_exits_2() {
    let out = crinn_cmd().arg("compact").output().expect("run crinn compact");
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--snapshot"), "stderr was: {stderr}");
}

#[test]
fn prompt_rejects_unknown_module() {
    let out = crinn_cmd()
        .args(["prompt", "--module", "bogus"])
        .output()
        .expect("run crinn prompt");
    assert_eq!(out.status.code(), Some(2));
}
