//! Mutation property tests — the live-traffic contract of
//! `MutableAnnIndex` (tombstone delete + online insert + consolidation)
//! for every natively-mutable index type, plus the coordinator's mixed
//! search+mutation serving path.
//!
//! The central acceptance property: after random interleaved
//! insert/delete/search sequences on HNSW, GLASS and IVF (and the
//! brute-force reference), a tombstoned id NEVER appears in
//! `search`/`search_batch` results, returned distances stay exact against
//! an externally-tracked mirror of the live set, and post-`consolidate()`
//! recall@10 over the live set clears the same static-build floor that
//! `tests/conformance.rs` asserts.

mod common;

use crinn::anns::{MutableAnnIndex, VectorSet};
use crinn::distance::Metric;
use crinn::util::rng::Rng;
use std::collections::BTreeMap;

/// Exact top-10 of the *live* mirror for one query (the oracle the
/// mutated index is graded against).
fn live_topk(live: &BTreeMap<u32, Vec<f32>>, q: &[f32], metric: Metric, k: usize) -> Vec<u32> {
    let mut all: Vec<(f32, u32)> = live
        .iter()
        .map(|(&id, v)| (metric.distance(q, v), id))
        .collect();
    all.sort_by(crinn::anns::heap::dist_cmp);
    all.truncate(k);
    all.into_iter().map(|(_, i)| i).collect()
}

/// Assert one round of searches: only live ids, exact distances, batch ==
/// per-query bitwise.
fn check_searches(
    idx: &dyn MutableAnnIndex,
    live: &BTreeMap<u32, Vec<f32>>,
    queries: &[&[f32]],
    metric: Metric,
    ef: usize,
    label: &str,
) {
    let per_query: Vec<Vec<(f32, u32)>> = queries
        .iter()
        .map(|q| idx.search_with_dists(q, 10, ef))
        .collect();
    let batched = idx.search_batch(queries, 10, ef);
    assert_eq!(batched, per_query, "{label}: batch != per-query under mutation");
    for (q, res) in queries.iter().zip(&per_query) {
        for &(d, id) in res {
            let v = live.get(&id).unwrap_or_else(|| {
                panic!("{label}: non-live id {id} surfaced (tombstone leak)")
            });
            assert!(!idx.is_deleted(id), "{label}: is_deleted({id}) disagrees");
            assert_eq!(d, metric.distance(q, v), "{label}: inexact distance for {id}");
        }
        // Distinct ids, sorted by (dist, id).
        let ids: std::collections::HashSet<u32> = res.iter().map(|&(_, i)| i).collect();
        assert_eq!(ids.len(), res.len(), "{label}: duplicate ids");
        for w in res.windows(2) {
            assert!(
                crinn::anns::heap::dist_cmp(&w[0], &w[1]) != std::cmp::Ordering::Greater,
                "{label}: unsorted results"
            );
        }
    }
}

/// The acceptance-criterion property, per index type and seed.
fn interleaved_property(case: &common::MutableCase, seed: u64) {
    let label = format!("{} seed {seed}", case.name);
    let ds = common::metric_dataset(Metric::L2, 900, 20, 1000 + seed);
    let mut idx = (case.build)(VectorSet::from_dataset(&ds), 7 + seed);
    let metric = ds.metric;
    let dim = ds.dim;

    // External mirror of the live set: id -> vector.
    let mut live: BTreeMap<u32, Vec<f32>> = (0..ds.n_base() as u32)
        .map(|i| (i, ds.base_vec(i as usize).to_vec()))
        .collect();
    let mut rng = Rng::new(0xD15E ^ seed);
    let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();

    for step in 0..120 {
        match rng.next_below(10) {
            0..=3 => {
                // Insert a fresh vector; the returned id must be a slot the
                // mirror does not consider live.
                let v: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
                let id = idx.insert(&v).unwrap_or_else(|e| panic!("{label}: insert: {e:#}"));
                assert!(
                    live.insert(id, v).is_none(),
                    "{label}: insert returned live id {id}"
                );
            }
            4..=6 => {
                // Delete a random live id (keep ≥ half the set alive so
                // recall floors stay meaningful).
                if live.len() > ds.n_base() / 2 {
                    let keys: Vec<u32> = live.keys().copied().collect();
                    let id = keys[rng.next_below(keys.len())];
                    idx.delete(id).unwrap_or_else(|e| panic!("{label}: delete {id}: {e:#}"));
                    live.remove(&id);
                    assert!(idx.is_deleted(id), "{label}: delete({id}) not visible");
                }
            }
            _ => {
                let qi = rng.next_below(queries.len());
                check_searches(&*idx, &live, &queries[qi..qi + 1], metric, case.ef, &label);
            }
        }
        assert_eq!(idx.live_count(), live.len(), "{label}: live_count drift at {step}");
        if step == 60 {
            // Mid-stream consolidation; everything must keep holding.
            idx.consolidate().unwrap_or_else(|e| panic!("{label}: consolidate: {e:#}"));
            assert_eq!(idx.deleted_count(), 0, "{label}: pending after consolidate");
            check_searches(&*idx, &live, &queries, metric, case.ef, &label);
        }
    }

    // Final consolidation, then the recall bar: recall@10 over the live
    // set must clear the same static-build floor conformance.rs asserts.
    idx.consolidate().unwrap();
    check_searches(&*idx, &live, &queries, metric, case.ef, &label);
    let mut acc = 0.0;
    for q in &queries {
        let found: Vec<u32> = idx.search(q, 10, case.ef);
        let gt = live_topk(&live, q, metric, 10);
        acc += crinn::dataset::gt::recall_at_k(&found, &gt, 10);
    }
    let recall = acc / queries.len() as f64;
    assert!(
        recall >= case.static_floor,
        "{label}: post-consolidate live-set recall {recall:.3} below static floor {}",
        case.static_floor
    );
}

#[test]
fn mutation_interleaved_property_bruteforce() {
    for seed in 0..2 {
        interleaved_property(&common::mutable_index_cases()[0], seed);
    }
}

#[test]
fn mutation_interleaved_property_hnsw() {
    for seed in 0..2 {
        interleaved_property(&common::mutable_index_cases()[1], seed);
    }
}

#[test]
fn mutation_interleaved_property_glass() {
    for seed in 0..2 {
        interleaved_property(&common::mutable_index_cases()[2], seed);
    }
}

#[test]
fn mutation_interleaved_property_ivf() {
    for seed in 0..2 {
        interleaved_property(&common::mutable_index_cases()[3], seed);
    }
}

/// Consolidation result-preservation, in its two strengths:
/// * IVF + brute force: **bitwise for every query even with pending
///   tombstones** (posting-list compaction keeps surviving order; the
///   flat scan has no structure at all);
/// * HNSW + GLASS (graph repair rewires edges, so post-repair results may
///   legitimately differ): a consolidate with **zero pending tombstones
///   is a strict no-op** — bitwise-identical results.
#[test]
fn mutation_consolidate_preserves_untouched_results() {
    let ds = common::metric_dataset(Metric::L2, 800, 20, 500);
    let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();
    for case in common::mutable_index_cases() {
        let mut idx = (case.build)(VectorSet::from_dataset(&ds), 7);
        // Delete a spread of ids.
        for id in (0..800u32).step_by(37) {
            idx.delete(id).unwrap();
        }
        if matches!(case.name, "bruteforce" | "ivf") {
            let before: Vec<_> = queries
                .iter()
                .map(|q| idx.search_with_dists(q, 10, case.ef))
                .collect();
            assert!(idx.consolidate().unwrap() > 0);
            let after: Vec<_> = queries
                .iter()
                .map(|q| idx.search_with_dists(q, 10, case.ef))
                .collect();
            assert_eq!(before, after, "{}: consolidate changed results", case.name);
        } else {
            idx.consolidate().unwrap();
        }
        // Second consolidate: no pending => strict no-op for everyone.
        let before: Vec<_> = queries
            .iter()
            .map(|q| idx.search_with_dists(q, 10, case.ef))
            .collect();
        assert_eq!(idx.consolidate().unwrap(), 0);
        let after: Vec<_> = queries
            .iter()
            .map(|q| idx.search_with_dists(q, 10, case.ef))
            .collect();
        assert_eq!(before, after, "{}: empty consolidate not a no-op", case.name);
    }
}

/// Vamana and NNDescent report `Unsupported` from every mutating method —
/// the uniform update path fails the request, never the process — while
/// the read-side accessors stay at the static defaults.
#[test]
fn mutation_unsupported_for_vamana_and_nndescent() {
    let ds = common::metric_dataset(Metric::L2, 300, 5, 501);
    let mut vam = crinn::anns::vamana::VamanaIndex::build(
        VectorSet::from_dataset(&ds),
        crinn::anns::vamana::VamanaParams::default(),
        1,
    );
    let mut nnd = crinn::anns::nndescent::NnDescentIndex::build(
        VectorSet::from_dataset(&ds),
        crinn::anns::nndescent::NnDescentParams::default(),
        1,
    );
    let v = ds.base_vec(0).to_vec();
    for idx in [&mut vam as &mut dyn MutableAnnIndex, &mut nnd as &mut dyn MutableAnnIndex] {
        let err = idx.insert(&v).expect_err("insert must be unsupported");
        assert!(format!("{err:#}").contains("Unsupported"));
        assert!(idx.delete(0).is_err());
        assert!(idx.consolidate().is_err());
        assert_eq!(idx.live_count(), 300);
        assert_eq!(idx.deleted_count(), 0);
        assert!(!idx.is_deleted(0));
        // Searches are untouched by the failed mutations.
        assert_eq!(idx.search(&v, 1, 64)[0], 0);
    }
}

/// Mixed search+mutation batches through the server: responses keyed back
/// to the right requests. Each search carries a distinct `k`, so a reply
/// routed to the wrong receiver is caught by its length; distances are
/// checked against the row store, which mutations never reorder
/// (tombstones filter, inserts append/recycle).
#[test]
fn mutation_mixed_batches_through_server_keyed_correctly() {
    use crinn::coordinator::{Server, ServerConfig, SharedMutableIndex};
    use std::sync::{Arc, RwLock};

    let ds = common::metric_dataset(Metric::L2, 500, 30, 502);
    let index: SharedMutableIndex = Arc::new(RwLock::new(Box::new(
        crinn::anns::bruteforce::BruteForceIndex::build(VectorSet::from_dataset(&ds)),
    )));
    let server = Server::start_mutable(
        index.clone(),
        ServerConfig {
            workers: 2,
            queue_depth: 512,
            batch: crinn::coordinator::batcher::BatchPolicy {
                max_batch: 16,
                max_wait: std::time::Duration::from_millis(2),
            },
        },
    );
    let h = server.handle();

    // Burst phase: interleave searches (distinct k per request) with
    // inserts and deletes, collect everything afterwards. Mutations may
    // land before or after any given search (concurrent workers), so the
    // assertions here are the timing-robust ones: reply keying (k and
    // query identity) and distance exactness against the append-only row
    // store.
    let mut rng = Rng::new(503);
    let mut search_pending = Vec::new();
    let mut insert_pending = Vec::new();
    let mut delete_pending = Vec::new();
    let mut expected_inserts = 0u64;
    let mut expected_deletes = 0u64;
    for i in 0..60usize {
        match i % 3 {
            0 => {
                let k = 1 + (i / 3) % 8;
                let qi = rng.next_below(ds.n_queries());
                let rx = h.submit(ds.query_vec(qi).to_vec(), k, 0).expect("accepted");
                search_pending.push((qi, k, rx));
            }
            1 => {
                let v: Vec<f32> = (0..ds.dim).map(|_| rng.next_gaussian_f32()).collect();
                let rx = h.submit_insert(v.clone()).expect("accepted");
                insert_pending.push((v, rx));
                expected_inserts += 1;
            }
            _ => {
                // Distinct original ids: never double-deleted.
                let id = (i / 3) as u32;
                delete_pending.push(h.submit_delete(id).expect("accepted"));
                expected_deletes += 1;
            }
        }
    }
    // Collect mutation acks first: a complete id -> vector map for every
    // row the searches might have seen. Original rows never move and
    // deletes never rewrite them (tombstones only; no consolidate here),
    // so ds rows stay authoritative for ids < 500 and the insert acks
    // cover the rest.
    let mut inserted: BTreeMap<u32, Vec<f32>> = BTreeMap::new();
    for (v, rx) in insert_pending {
        let resp = rx.recv().expect("insert answered");
        let id = resp.result.expect("insert failed");
        assert!(inserted.insert(id, v).is_none(), "duplicate insert id {id}");
    }
    for rx in delete_pending {
        let resp = rx.recv().expect("delete answered");
        assert!(resp.result.is_ok(), "delete failed: {:?}", resp.result);
    }
    for (qi, k, rx) in search_pending {
        let resp = rx.recv().expect("search answered");
        assert_eq!(resp.ids.len(), k, "response keyed to the wrong request");
        assert_eq!(resp.dists.len(), k);
        let q = ds.query_vec(qi);
        for (&id, &d) in resp.ids.iter().zip(&resp.dists) {
            let row: &[f32] = if (id as usize) < ds.n_base() {
                ds.base_vec(id as usize)
            } else {
                inserted
                    .get(&id)
                    .unwrap_or_else(|| panic!("unknown id {id} in response"))
            };
            assert_eq!(d, ds.metric.distance(q, row), "query {qi} id {id}");
        }
    }
    let snap = server.shutdown();
    assert_eq!(snap.inserts, expected_inserts);
    assert_eq!(snap.deletes, expected_deletes);
    assert_eq!(snap.mutation_errors, 0);
    assert_eq!(snap.requests, 20);
    assert_eq!(
        snap.live_points,
        500 + expected_inserts - expected_deletes,
        "live gauge must reconcile with applied mutations"
    );
    // The sequential epilogue is fully deterministic: an acked delete is
    // invisible to the next search, an acked insert is findable.
    let index2 = index.clone();
    let server = Server::start_mutable(index2, ServerConfig::default());
    let h = server.handle();
    let probe = ds.query_vec(3).to_vec();
    let ack = h.insert(probe.clone()).unwrap();
    let new_id = ack.result.expect("insert ok");
    let resp = h.query(probe.clone(), 1, 0).unwrap();
    assert_eq!((resp.ids[0], resp.dists[0]), (new_id, 0.0));
    let ack = h.delete(new_id).unwrap();
    assert_eq!(ack.result, Ok(new_id));
    let resp = h.query(probe, 1, 0).unwrap();
    assert_ne!(resp.ids[0], new_id, "acked delete resurfaced");
    server.shutdown();
}
