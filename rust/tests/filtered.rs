//! Filtered-search property tests — the live-traffic contract of
//! `search_filtered_with_dists` under mutation, mirroring
//! `tests/mutation.rs`: random interleaved insert/delete/consolidate/
//! filtered-search sequences on every natively-mutable index type,
//! graded against an externally-tracked mirror of the live set AND its
//! metadata.
//!
//! The central properties:
//! * a filtered search NEVER surfaces a tombstoned id or an id outside
//!   the filter, at any point in the interleaving;
//! * returned distances stay exact against the mirror;
//! * filtered batch == filtered per-query, bitwise;
//! * `filter=None` is bitwise identical to the unfiltered entry points;
//! * a filter below the brute-force fallback threshold answers bitwise
//!   identically to the exact oracle over the live matching set — even
//!   mid-mutation, and even when the filter still names deleted ids;
//! * post-consolidation filtered recall over the live matching set
//!   clears a loosened static floor.

mod common;

use crinn::anns::{FilterBitset, FilterExpr, MetadataStore, MutableAnnIndex, VectorSet};
use crinn::distance::Metric;
use crinn::util::rng::Rng;
use std::collections::{BTreeMap, BTreeSet};

/// Exact top-k of the live mirror restricted to `keep`, sorted by
/// (dist, id) — the oracle filtered searches are graded against.
fn live_filtered_topk(
    live: &BTreeMap<u32, Vec<f32>>,
    keep: impl Fn(u32) -> bool,
    q: &[f32],
    metric: Metric,
    k: usize,
) -> Vec<(f32, u32)> {
    let mut all: Vec<(f32, u32)> = live
        .iter()
        .filter(|(&id, _)| keep(id))
        .map(|(&id, v)| (metric.distance(q, v), id))
        .collect();
    all.sort_by(crinn::anns::heap::dist_cmp);
    all.truncate(k);
    all
}

/// The metadata mirror: tenant group `t{id % 4}` for every id, tag
/// `"vip"` on ids divisible by 50 (rare enough to stay below the default
/// fallback threshold for the whole run).
fn assign(meta: &mut MetadataStore, vip: &mut BTreeSet<u32>, id: u32) {
    let tenant = format!("t{}", id % 4);
    if id % 50 == 0 {
        vip.insert(id);
        meta.set_for(id, Some(&tenant), &["vip"]);
    } else {
        // Inserts can recycle a consolidated slot that used to be vip.
        vip.remove(&id);
        meta.set_for(id, Some(&tenant), &[]);
    }
}

/// One round of filtered checks against the mirrors.
fn check_filtered(
    idx: &dyn MutableAnnIndex,
    live: &BTreeMap<u32, Vec<f32>>,
    vip: &BTreeSet<u32>,
    meta: &MetadataStore,
    queries: &[&[f32]],
    metric: Metric,
    ef: usize,
    label: &str,
) {
    let n = idx.len();

    // --- Tenant filter (~25% selectivity): beam / scan path.
    let tenant_filter = meta.compile(&FilterExpr::tenant("t1"), n);
    let per_query: Vec<Vec<(f32, u32)>> = queries
        .iter()
        .map(|q| idx.search_filtered_with_dists(q, 10, ef, Some(&tenant_filter)))
        .collect();
    assert_eq!(
        idx.search_filtered_batch(queries, 10, ef, Some(&tenant_filter)),
        per_query,
        "{label}: filtered batch != per-query"
    );
    for (q, res) in queries.iter().zip(&per_query) {
        for &(d, id) in res {
            assert!(id % 4 == 1, "{label}: id {id} outside tenant filter");
            assert!(!idx.is_deleted(id), "{label}: tombstoned id {id} surfaced");
            let v = live
                .get(&id)
                .unwrap_or_else(|| panic!("{label}: non-live id {id} surfaced"));
            assert_eq!(d, metric.distance(q, v), "{label}: inexact distance for {id}");
        }
        let ids: std::collections::HashSet<u32> = res.iter().map(|&(_, i)| i).collect();
        assert_eq!(ids.len(), res.len(), "{label}: duplicate ids");
        for w in res.windows(2) {
            assert!(
                crinn::anns::heap::dist_cmp(&w[0], &w[1]) != std::cmp::Ordering::Greater,
                "{label}: unsorted filtered results"
            );
        }
    }

    // --- filter=None is bitwise the unfiltered path.
    let unfiltered: Vec<Vec<(f32, u32)>> = queries
        .iter()
        .map(|q| idx.search_with_dists(q, 10, ef))
        .collect();
    let none: Vec<Vec<(f32, u32)>> = queries
        .iter()
        .map(|q| idx.search_filtered_with_dists(q, 10, ef, None))
        .collect();
    assert_eq!(none, unfiltered, "{label}: filter=None diverges per-query");
    assert_eq!(
        idx.search_filtered_batch(queries, 10, ef, None),
        unfiltered,
        "{label}: filter=None diverges batched"
    );

    // --- Rare "vip" filter: below the fallback threshold, so the answer
    // must be bitwise the exact oracle over the live matching set. The
    // bitset still names deleted vip ids — they must not resurface.
    let vip_filter = meta.compile(&FilterExpr::tag("vip"), n);
    assert!(
        vip_filter.count() <= crinn::anns::filter::DEFAULT_FILTERED_FALLBACK,
        "{label}: vip fixture grew past the fallback threshold"
    );
    for q in queries {
        let got = idx.search_filtered_with_dists(q, 10, ef, Some(&vip_filter));
        let want = live_filtered_topk(live, |id| vip.contains(&id), q, metric, 10);
        assert_eq!(got, want, "{label}: rare-filter fallback != exact oracle");
    }
}

/// The acceptance property, per mutable index type and seed.
fn interleaved_filtered_property(case: &common::MutableCase, seed: u64) {
    let label = format!("{} seed {seed}", case.name);
    let ds = common::metric_dataset(Metric::L2, 900, 20, 2000 + seed);
    let mut idx = (case.build)(VectorSet::from_dataset(&ds), 7 + seed);
    let metric = ds.metric;
    let dim = ds.dim;

    // External mirrors: live set (id -> vector), metadata store, vip set.
    let mut live: BTreeMap<u32, Vec<f32>> = (0..ds.n_base() as u32)
        .map(|i| (i, ds.base_vec(i as usize).to_vec()))
        .collect();
    let mut meta = MetadataStore::new();
    let mut vip: BTreeSet<u32> = BTreeSet::new();
    for id in 0..ds.n_base() as u32 {
        assign(&mut meta, &mut vip, id);
    }
    let mut rng = Rng::new(0xF117 ^ seed);
    let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();

    for step in 0..100 {
        match rng.next_below(10) {
            0..=3 => {
                let v: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
                let id = idx.insert(&v).unwrap_or_else(|e| panic!("{label}: insert: {e:#}"));
                assert!(
                    live.insert(id, v).is_none(),
                    "{label}: insert returned live id {id}"
                );
                assign(&mut meta, &mut vip, id);
            }
            4..=6 => {
                if live.len() > ds.n_base() / 2 {
                    let keys: Vec<u32> = live.keys().copied().collect();
                    let id = keys[rng.next_below(keys.len())];
                    idx.delete(id).unwrap_or_else(|e| panic!("{label}: delete {id}: {e:#}"));
                    // Metadata is NOT erased on delete: the filter keeps
                    // naming the id, the tombstone must hide it.
                    live.remove(&id);
                }
            }
            _ => {
                let qi = rng.next_below(queries.len());
                check_filtered(
                    &*idx,
                    &live,
                    &vip,
                    &meta,
                    &queries[qi..qi + 1],
                    metric,
                    case.ef,
                    &label,
                );
            }
        }
        if step == 50 {
            idx.consolidate().unwrap_or_else(|e| panic!("{label}: consolidate: {e:#}"));
            check_filtered(&*idx, &live, &vip, &meta, &queries, metric, case.ef, &label);
        }
    }

    // Final consolidation, full check, then the filtered recall bar over
    // the live tenant-t1 set (loosened: ~25% of visited nodes admissible).
    idx.consolidate().unwrap();
    check_filtered(&*idx, &live, &vip, &meta, &queries, metric, case.ef, &label);
    let tenant_filter = meta.compile(&FilterExpr::tenant("t1"), idx.len());
    let mut acc = 0.0;
    for q in &queries {
        let found: Vec<u32> = idx
            .search_filtered_with_dists(q, 10, case.ef, Some(&tenant_filter))
            .into_iter()
            .map(|(_, i)| i)
            .collect();
        let gt: Vec<u32> = live_filtered_topk(&live, |id| id % 4 == 1, q, metric, 10)
            .into_iter()
            .map(|(_, i)| i)
            .collect();
        acc += crinn::dataset::gt::recall_at_k(&found, &gt, 10);
    }
    let recall = acc / queries.len() as f64;
    let floor = if case.name == "bruteforce" {
        0.999
    } else {
        (case.static_floor - 0.25).max(0.10)
    };
    assert!(
        recall >= floor,
        "{label}: post-consolidate filtered recall {recall:.3} below floor {floor}"
    );
}

#[test]
fn filtered_interleaved_property_bruteforce() {
    for seed in 0..2 {
        interleaved_filtered_property(&common::mutable_index_cases()[0], seed);
    }
}

#[test]
fn filtered_interleaved_property_hnsw() {
    for seed in 0..2 {
        interleaved_filtered_property(&common::mutable_index_cases()[1], seed);
    }
}

#[test]
fn filtered_interleaved_property_glass() {
    for seed in 0..2 {
        interleaved_filtered_property(&common::mutable_index_cases()[2], seed);
    }
}

#[test]
fn filtered_interleaved_property_ivf() {
    for seed in 0..2 {
        interleaved_filtered_property(&common::mutable_index_cases()[3], seed);
    }
}

/// An out-of-range / empty filter is deny-safe: a bitset sized smaller
/// than the index never surfaces ids beyond its range, and an all-zero
/// bitset returns nothing from every index type.
#[test]
fn filtered_deny_safe_bitsets() {
    let ds = common::metric_dataset(Metric::L2, 400, 5, 3000);
    let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();
    for case in common::static_index_cases() {
        let idx = (case.build)(VectorSet::from_dataset(&ds), 7);
        let empty = FilterBitset::new(ds.n_base());
        let short = FilterBitset::from_predicate(100, |_| true);
        for q in &queries {
            assert!(
                idx.search_filtered_with_dists(q, 10, case.ef, Some(&empty)).is_empty(),
                "{}: empty filter returned results",
                case.name
            );
            for (_, id) in idx.search_filtered_with_dists(q, 10, case.ef, Some(&short)) {
                assert!(id < 100, "{}: id {id} beyond the bitset range", case.name);
            }
        }
    }
}
