//! Figure 1: QPS versus recall curves across six datasets × eight systems.
//!
//! Regenerates the paper's headline figure at sandbox scale: for every
//! Table-2 dataset, builds {CRINN, GLASS, ParlayANN, NNDescent,
//! PyNNDescent, Vearch-IVF, IVF-PQ, Voyager}, sweeps ef, and emits
//! `reports/fig1_qps_recall.csv` + per-dataset ASCII panels.
//!
//! Expected *shape* (what the paper claims and we check in EXPERIMENTS.md):
//! CRINN ≥ GLASS everywhere; graph methods dominate IVF at high recall;
//! the nytimes-like high-noise angular dataset is the hardest.

use crinn::eval::harness;
use crinn::eval::report;

fn main() {
    if let Some(b) = crinn::eval::batch_mode() {
        eprintln!(
            "[fig1] CRINN_BATCH={b}: sweeps use the batched-throughput protocol \
             (recall identical to per-query; see eval::sweep)"
        );
    }
    let ef_grid = harness::bench_ef_grid();
    let datasets = harness::bench_dataset_names();
    let mut all = Vec::new();
    for name in &datasets {
        eprintln!("[fig1] dataset {name}");
        let ds = match harness::bench_dataset(name, crinn::DEFAULT_K) {
            Ok(ds) => ds,
            Err(e) => {
                eprintln!("[fig1] skipping {name}: {e:#}");
                continue;
            }
        };
        let mut panel = Vec::new();
        for (label, builder) in harness::algorithms() {
            let sweep = harness::run_algorithm(&ds, label, builder, &ef_grid);
            panel.push(sweep.clone());
            all.push(sweep);
        }
        println!(
            "{}",
            report::ascii_plot(&format!("Figure 1 — {name}"), &panel, 64, 16)
        );
    }
    let csv = report::sweeps_to_csv(&all);
    let path = harness::reports_dir().join("fig1_qps_recall.csv");
    report::save(&path, &csv).expect("write csv");
    println!("wrote {} ({} rows)", path.display(), csv.lines().count() - 1);
}
