//! Table 4: progressive per-module improvements (§5.3).
//!
//! For each dataset, evaluates the four cumulative stages
//! (GLASS baseline → +graph-construction → +search → +refinement, the
//! §3.5 optimization order) and reports the average QPS improvement over
//! the recall targets {0.90, 0.95, 0.99, 0.999}, individual and
//! cumulative — the paper's Table 4 columns.
//! Output: stdout markdown + `reports/table4_progressive.{md,csv}`.

use crinn::eval::harness;
use crinn::eval::{qps_at_recall, report};
use crinn::variants::VariantConfig;
use std::fmt::Write as _;

const TARGETS: [f64; 4] = [0.90, 0.95, 0.99, 0.999];

fn main() {
    let ef_grid = harness::bench_ef_grid();
    let datasets = harness::bench_dataset_names();
    let stages = VariantConfig::progressive_stages();
    let mut md = String::from(
        "| Dataset | +Construction (ind/cum) | +Search (ind/cum) | +Refinement (ind/cum) |\n|---|---|---|---|\n",
    );
    let mut csv = String::from("dataset,stage,individual_pct,cumulative_pct\n");
    let mut overall: Vec<Vec<f64>> = vec![Vec::new(); 3];

    for name in &datasets {
        eprintln!("[table4] dataset {name}");
        let ds = match harness::bench_dataset(name, crinn::DEFAULT_K) {
            Ok(ds) => ds,
            Err(e) => {
                eprintln!("[table4] skipping {name}: {e:#}");
                continue;
            }
        };
        let mut stage_qps = Vec::new();
        for (label, cfg) in &stages {
            let idx = crinn::anns::glass::GlassIndex::build(
                crinn::anns::VectorSet::from_dataset(&ds),
                cfg.clone(),
                42,
            )
            .with_label(label);
            let sweep = crinn::eval::sweep_index(&idx, &ds, ds.gt_k, &ef_grid, 0.0);
            let qs: Vec<f64> = TARGETS
                .iter()
                .filter_map(|&t| qps_at_recall(&sweep.points, t))
                .collect();
            let avg = if qs.is_empty() {
                f64::NAN
            } else {
                qs.iter().sum::<f64>() / qs.len() as f64
            };
            eprintln!("  {label:<22} avg-QPS {avg:.0}");
            stage_qps.push(avg);
        }
        let base = stage_qps[0];
        let mut cells = Vec::new();
        for s in 1..stages.len() {
            let cum = (stage_qps[s] / base - 1.0) * 100.0;
            let ind = (stage_qps[s] / stage_qps[s - 1] - 1.0) * 100.0;
            cells.push(format!("{ind:+.2}% / {cum:+.2}%"));
            let _ = writeln!(csv, "{name},{},{ind:.2},{cum:.2}", stages[s].0);
            if ind.is_finite() {
                overall[s - 1].push(ind);
            }
        }
        let _ = writeln!(md, "| {name} | {} | {} | {} |", cells[0], cells[1], cells[2]);
    }
    let avg = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let _ = writeln!(
        md,
        "| **average (individual)** | {:+.2}% | {:+.2}% | {:+.2}% |",
        avg(&overall[0]),
        avg(&overall[1]),
        avg(&overall[2])
    );
    println!("\n## Table 4 — progressive per-module improvement (sandbox scale)\n\n{md}");
    let dir = harness::reports_dir();
    report::save(&dir.join("table4_progressive.md"), &md).unwrap();
    report::save(&dir.join("table4_progressive.csv"), &csv).unwrap();
    println!("wrote reports/table4_progressive.{{md,csv}}");
}
