//! Cold-start bench for the disk-resident storage tier (DESIGN.md
//! §Storage-Tier): how fast does a saved index come back up, and at what
//! resident-memory cost, heap load vs mmap serving?
//!
//! For each scale, builds a GLASS index, saves a v3 snapshot, then
//! measures per serving tier:
//!
//! * `load_s` — snapshot open → index ready;
//! * `first_query_s` — one query through the freshly loaded index (for
//!   mmap this includes the first page faults on the touched sections);
//! * `queries_s` — the full query set, batched;
//! * `rss_delta_kb` — VmRSS growth across load + queries (Linux
//!   `/proc/self/status`; 0 elsewhere);
//! * a `replay` row — restart with a 200-record mutation log tail, and a
//!   `compact` row — folding that log into a fresh snapshot.
//!
//! Emits `reports/restart.csv`. Scale override: `CRINN_BENCH_RESTART_N`
//! (comma list, e.g. `100000,1000000` — the 1M row is opt-in; the
//! default 100k keeps `make bench-restart` minutes, not tens of them).

use crinn::anns::glass::GlassIndex;
use crinn::anns::persist::{load_glass, load_glass_mmap, save_glass};
use crinn::anns::store::{compact_glass, restore_glass, VectorLog};
use crinn::anns::{AnnIndex, MutableAnnIndex, VectorSet};
use crinn::dataset::synth;
use crinn::eval::harness;
use crinn::eval::report;
use crinn::variants::VariantConfig;
use std::fmt::Write as _;
use std::time::Instant;

/// VmRSS in kB from /proc/self/status (0 when unavailable).
fn rss_kb() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    status
        .lines()
        .find_map(|l| l.strip_prefix("VmRSS:"))
        .and_then(|l| l.trim().trim_end_matches("kB").trim().parse().ok())
        .unwrap_or(0)
}

fn scales() -> Vec<usize> {
    match std::env::var("CRINN_BENCH_RESTART_N") {
        Ok(s) => s
            .split(',')
            .filter(|t| !t.trim().is_empty())
            .map(|t| {
                t.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("CRINN_BENCH_RESTART_N: bad integer {t:?}"))
            })
            .collect(),
        Err(_) => vec![100_000],
    }
}

fn main() -> crinn::Result<()> {
    let mut csv = String::from(
        "n,tier,snapshot_bytes,load_s,first_query_s,queries_s,rss_delta_kb,extra\n",
    );
    for n in scales() {
        let nq = 200;
        eprintln!("== restart bench: n={n}, {nq} queries ==");
        let ds = synth::generate_counts(synth::spec("demo-64").unwrap(), n, nq, 42);
        let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();

        let t = Instant::now();
        let idx = GlassIndex::build(VectorSet::from_dataset(&ds), VariantConfig::crinn_full(), 42);
        eprintln!("  built in {:.2}s", t.elapsed().as_secs_f64());
        let snap = std::env::temp_dir().join(format!("crinn_bench_restart_{n}.idx"));
        let t = Instant::now();
        save_glass(&idx, &snap)?;
        let snapshot_bytes = std::fs::metadata(&snap)?.len();
        eprintln!(
            "  saved {snapshot_bytes} bytes in {:.2}s",
            t.elapsed().as_secs_f64()
        );
        drop(idx);

        for tier in ["heap", "mmap"] {
            let rss0 = rss_kb();
            let t = Instant::now();
            let loaded = match tier {
                "heap" => load_glass(&snap)?,
                _ => load_glass_mmap(&snap)?,
            };
            let load_s = t.elapsed().as_secs_f64();
            let t = Instant::now();
            let first = loaded.search_with_dists(queries[0], 10, 64);
            let first_query_s = t.elapsed().as_secs_f64();
            assert_eq!(first.len(), 10);
            let t = Instant::now();
            let results = loaded.search_batch(&queries, 10, 64);
            let queries_s = t.elapsed().as_secs_f64();
            assert_eq!(results.len(), queries.len());
            let rss_delta = rss_kb().saturating_sub(rss0);
            eprintln!(
                "  [{tier}] load={load_s:.4}s first_query={first_query_s:.5}s \
                 {nq}_queries={queries_s:.3}s rss_delta={rss_delta}kB"
            );
            let _ = writeln!(
                csv,
                "{n},{tier},{snapshot_bytes},{load_s:.6},{first_query_s:.6},{queries_s:.6},{rss_delta},"
            );
            drop(loaded);
        }

        // Restart with a log tail: 100 inserts + 100 deletes to replay.
        let log_path = std::env::temp_dir().join(format!("crinn_bench_restart_{n}.wal"));
        {
            let mut live = load_glass(&snap)?;
            let mut log = VectorLog::create(&log_path)?;
            for qi in 0..100 {
                let id = live.insert(ds.query_vec(qi % nq))?;
                log.append_vector(id, ds.query_vec(qi % nq))?;
            }
            for id in 0..100u32 {
                live.delete(id * 7)?;
                log.append_tombstone(id * 7)?;
            }
        }
        for tier in ["heap", "mmap"] {
            let rss0 = rss_kb();
            let t = Instant::now();
            let restored = restore_glass(&snap, &log_path, tier == "mmap")?;
            let load_s = t.elapsed().as_secs_f64();
            let rss_delta = rss_kb().saturating_sub(rss0);
            eprintln!(
                "  [replay-{tier}] restore+replay({})={load_s:.4}s rss_delta={rss_delta}kB",
                restored.replayed
            );
            let _ = writeln!(
                csv,
                "{n},replay-{tier},{snapshot_bytes},{load_s:.6},,,{rss_delta},replayed={}",
                restored.replayed
            );
            if tier == "mmap" {
                // Compaction timing: fold the log into a fresh snapshot.
                let mut r = restored;
                let compact_to = std::env::temp_dir().join(format!("crinn_bench_compact_{n}.idx"));
                let t = Instant::now();
                let stats = compact_glass(&mut r.index, &r.metadata, &mut r.log, &compact_to)?;
                let compact_s = t.elapsed().as_secs_f64();
                eprintln!(
                    "  [compact] {compact_s:.3}s dropped={} truncated={}B",
                    stats.dropped, stats.log_bytes_truncated
                );
                let _ = writeln!(
                    csv,
                    "{n},compact,{},{compact_s:.6},,,,dropped={}",
                    std::fs::metadata(&compact_to)?.len(),
                    stats.dropped
                );
                std::fs::remove_file(&compact_to).ok();
            }
        }
        std::fs::remove_file(&snap).ok();
        std::fs::remove_file(&log_path).ok();
    }
    let path = harness::reports_dir().join("restart.csv");
    report::save(&path, &csv)?;
    eprintln!("wrote {}", path.display());
    Ok(())
}
