//! Micro-bench: graph-search building blocks — visited-set strategies,
//! heap ops, end-to-end beam search, and knob ablations (the §Perf
//! evidence for the data-structure choices DESIGN.md §7 calls out).

use crinn::anns::heap::{MinQueue, TopK};
use crinn::anns::visited::VisitedSet;
use crinn::anns::{AnnIndex, VectorSet};
use crinn::dataset::synth;
use crinn::util::bench::{report_row, time_adaptive};
use crinn::util::rng::Rng;
use crinn::variants::{ConstructionKnobs, SearchKnobs};
use std::collections::HashSet;
use std::hint::black_box;

fn main() {
    let mut rng = Rng::new(1);
    let n = 100_000;

    // --- visited set: epoch-stamped vs HashSet.
    println!("## visited-set strategies ({n} nodes, 2000 marks/query)\n");
    let ids: Vec<u32> = (0..2000).map(|_| rng.next_below(n) as u32).collect();
    let mut vs = VisitedSet::new(n);
    let s = time_adaptive(0.3, 200, || {
        vs.clear();
        for &i in &ids {
            black_box(vs.insert(i));
        }
    });
    report_row("epoch-stamped VisitedSet", &s);
    let s = time_adaptive(0.3, 200, || {
        let mut h = HashSet::with_capacity(2048);
        for &i in &ids {
            black_box(h.insert(i));
        }
    });
    report_row("HashSet<u32>", &s);

    // --- heaps.
    println!("\n## heap ops (1000 push + drain)\n");
    let vals: Vec<f32> = (0..1000).map(|_| rng.next_f32()).collect();
    let s = time_adaptive(0.3, 200, || {
        let mut q = MinQueue::with_capacity(1024);
        for (i, &v) in vals.iter().enumerate() {
            q.push(v, i as u32);
        }
        while let Some(x) = q.pop() {
            black_box(x);
        }
    });
    report_row("MinQueue push+drain", &s);
    let s = time_adaptive(0.3, 200, || {
        let mut t = TopK::new(64);
        for (i, &v) in vals.iter().enumerate() {
            t.push(v, i as u32);
        }
        black_box(t.bound());
    });
    report_row("TopK(64) stream", &s);

    // --- end-to-end beam search knob ablation. The edge_batch rows go
    // through the one-to-many SIMD kernel (distance::simd), so the active
    // dispatch matters when comparing against the baseline rows.
    println!(
        "\n## beam search knob ablation (demo-64, 8k nodes, ef=64, dispatch: {})\n",
        crinn::distance::simd::kernels().name
    );
    let sp = synth::spec("demo-64").unwrap();
    let ds = synth::generate_counts(sp, 8_000, 64, 3);
    let graph = crinn::anns::hnsw::builder::build(
        VectorSet::from_dataset(&ds),
        &ConstructionKnobs::default(),
        7,
    );
    let mut ctx = crinn::anns::hnsw::search::SearchContext::new(graph.len());
    for (label, knobs) in [
        ("baseline knobs", SearchKnobs::default()),
        (
            "edge_batch",
            SearchKnobs {
                edge_batch: true,
                batch_size: 32,
                ..Default::default()
            },
        ),
        (
            "early_termination",
            SearchKnobs {
                early_termination: true,
                patience: 4,
                ..Default::default()
            },
        ),
        ("crinn discovered", SearchKnobs::crinn_discovered()),
    ] {
        let mut qi = 0;
        let s = time_adaptive(0.5, 200, || {
            qi = (qi + 1) % ds.n_queries();
            black_box(crinn::anns::hnsw::search::search(
                &graph,
                &knobs,
                &mut ctx,
                ds.query_vec(qi),
                10,
                64,
            ));
        });
        report_row(label, &s);
    }

    // --- multi-query batch search: the per-query trait path (one scratch
    // checkout per query) vs `search_batch` (one checkout per batch, warm
    // context across the whole batch). Results are bitwise identical, so
    // any gap is pure per-query overhead + cache effects — the speedup the
    // batch-first serving pipeline banks on.
    println!(
        "\n## multi-query batch search (hnsw, 8k nodes, {} queries, k=10, ef=64)\n",
        ds.n_queries()
    );
    let idx = crinn::anns::hnsw::HnswIndex::build(
        VectorSet::from_dataset(&ds),
        &ConstructionKnobs::default(),
        SearchKnobs::crinn_discovered(),
        7,
    );
    let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();
    let s = time_adaptive(0.5, 20, || {
        for q in &queries {
            black_box(idx.search_with_dists(q, 10, 64));
        }
    });
    report_row("per-query search_with_dists", &s);
    for bs in [8usize, 32, 64] {
        let s = time_adaptive(0.5, 20, || {
            for chunk in queries.chunks(bs) {
                black_box(idx.search_batch(chunk, 10, 64));
            }
        });
        report_row(&format!("search_batch B={bs}"), &s);
    }
}
