//! Closed-loop throughput bench for the network serving edge
//! (DESIGN.md §Network-Edge): N clients, each firing the next search the
//! moment the previous response lands, over real loopback sockets — vs
//! the same closed loop through the in-process `ServerHandle`, which
//! prices the wire (frame encode/decode, syscalls, event-loop hop)
//! separately from the serving path itself.
//!
//! Emits `reports/net_qps.csv`:
//! `mode,clients,requests,elapsed_s,qps,p50_us,p99_us`.
//!
//! Overrides: `CRINN_BENCH_NET_N` (base vectors, default 20000),
//! `CRINN_BENCH_NET_REQUESTS` (total per row, default 4000),
//! `CRINN_BENCH_NET_CLIENTS` (comma list, default `1,4,16`).

#[cfg(not(unix))]
fn main() {
    eprintln!("net_qps: the socket front end is unix-only; skipping");
}

#[cfg(unix)]
fn main() -> crinn::Result<()> {
    use crinn::anns::glass::GlassIndex;
    use crinn::anns::{AnnIndex, VectorSet};
    use crinn::coordinator::{Client, NetConfig, NetServer, Server};
    use crinn::dataset::synth;
    use crinn::eval::{harness, report};
    use crinn::util::bench::Stats;
    use crinn::variants::VariantConfig;
    use std::fmt::Write as _;
    use std::sync::Arc;
    use std::time::Instant;

    let env_usize = |key: &str, default: usize| -> usize {
        std::env::var(key)
            .ok()
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(default)
    };
    let n = env_usize("CRINN_BENCH_NET_N", 20_000);
    let requests = env_usize("CRINN_BENCH_NET_REQUESTS", 4_000);
    let client_counts: Vec<usize> = match std::env::var("CRINN_BENCH_NET_CLIENTS") {
        Ok(s) => s
            .split(',')
            .filter(|t| !t.trim().is_empty())
            .map(|t| {
                t.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("CRINN_BENCH_NET_CLIENTS: bad integer {t:?}"))
            })
            .collect(),
        Err(_) => vec![1, 4, 16],
    };
    let (k, ef) = (10, 64);

    eprintln!("== net_qps: {n} base vectors, {requests} requests per row ==");
    let ds = synth::generate_counts(synth::spec("demo-64").unwrap(), n, 200, 42);
    let t = Instant::now();
    let index: Arc<dyn AnnIndex> = Arc::new(GlassIndex::build(
        VectorSet::from_dataset(&ds),
        VariantConfig::crinn_full(),
        42,
    ));
    eprintln!("  built in {:.2}s", t.elapsed().as_secs_f64());
    let net = NetServer::start(
        Server::start(index, Default::default()),
        "127.0.0.1:0",
        NetConfig::default(),
    )?;
    let addr = net.addr().to_string();
    let queries: Arc<Vec<Vec<f32>>> = Arc::new(
        (0..ds.n_queries()).map(|qi| ds.query_vec(qi).to_vec()).collect(),
    );

    let mut csv = String::from("mode,clients,requests,elapsed_s,qps,p50_us,p99_us\n");
    for &clients in &client_counts {
        let per_client = requests / clients.max(1);
        for mode in ["in-process", "net"] {
            let t = Instant::now();
            let workers: Vec<_> = (0..clients)
                .map(|c| {
                    let queries = queries.clone();
                    let addr = addr.clone();
                    let handle = net.handle();
                    std::thread::spawn(move || {
                        let mut lat = Vec::with_capacity(per_client);
                        let mut client = (mode == "net")
                            .then(|| Client::connect(&addr, "bench").unwrap());
                        for r in 0..per_client {
                            let q = &queries[(c * per_client + r) % queries.len()];
                            let t = Instant::now();
                            match &mut client {
                                Some(cl) => {
                                    cl.search(q, k, ef).expect("wire search");
                                }
                                None => {
                                    handle.query(q.clone(), k, ef).expect("in-process search");
                                }
                            }
                            lat.push(t.elapsed().as_secs_f64());
                        }
                        lat
                    })
                })
                .collect();
            let mut lat = Vec::with_capacity(requests);
            for w in workers {
                lat.extend(w.join().expect("bench client thread"));
            }
            let elapsed = t.elapsed().as_secs_f64();
            let stats = Stats::from_samples(lat);
            let qps = stats.n as f64 / elapsed;
            eprintln!(
                "  {mode:<10} clients={clients:<3} {qps:>8.0} qps  p50 {:>7.1}us  p99 {:>7.1}us",
                stats.p50 * 1e6,
                stats.p99 * 1e6
            );
            writeln!(
                csv,
                "{mode},{clients},{},{elapsed:.3},{qps:.0},{:.1},{:.1}",
                stats.n,
                stats.p50 * 1e6,
                stats.p99 * 1e6
            )
            .unwrap();
        }
    }
    let snap = net.shutdown();
    eprintln!(
        "  served {} searches over {} connections ({} frames)",
        snap.requests, snap.connections, snap.protocol_frames
    );
    let path = harness::reports_dir().join("net_qps.csv");
    report::save(&path, &csv)?;
    eprintln!("wrote {}", path.display());
    Ok(())
}
