//! Filtered-QPS vs selectivity sweep — the bench hook for the filtered
//! search path (DESIGN.md §Filtered-search).
//!
//! For each bench dataset, builds the Figure-1 algorithm roster and
//! measures filtered recall@k / QPS against *filtered* ground truth at
//! three selectivity tiers (~90%, ~10%, ~1% of the base set matching).
//! The 1% tier typically lands below the brute-force fallback threshold,
//! so this sweep exercises both the admit-filtered beam path and the
//! exact fallback. Emits `reports/filtered_sweep.csv` with one row per
//! (dataset, algorithm, tier, ef).
//!
//! Scale/grid env overrides as in the other benches: `CRINN_BENCH_N`,
//! `CRINN_BENCH_QUERIES`, `CRINN_BENCH_EF`, `CRINN_BENCH_DATASETS`.

use crinn::anns::FilterBitset;
use crinn::eval::harness;
use crinn::eval::report;
use std::fmt::Write as _;

fn main() -> crinn::Result<()> {
    let k = crinn::DEFAULT_K;
    let ef_grid = harness::bench_ef_grid();
    let mut csv = String::from(
        "dataset,algorithm,filter,selectivity,popcount,k,ef,recall,qps,mean_latency_s,p99_latency_s\n",
    );
    for name in harness::bench_dataset_names() {
        let ds = harness::bench_dataset(&name, k)?;
        eprintln!(
            "== {} (n={}, {} queries, k={k}) ==",
            ds.name,
            ds.n_base(),
            ds.n_queries()
        );
        // Modulus predicates over the id space: selectivity is exact and
        // reproducible without a metadata store in the loop.
        let tiers: Vec<(&str, FilterBitset)> = vec![
            ("sel90", FilterBitset::from_predicate(ds.n_base(), |id| id % 10 != 0)),
            ("sel10", FilterBitset::from_predicate(ds.n_base(), |id| id % 10 == 0)),
            ("sel1", FilterBitset::from_predicate(ds.n_base(), |id| id % 100 == 0)),
        ];
        for (label, builder) in harness::algorithms() {
            let index = builder(&ds, 42);
            for (tier, filter) in &tiers {
                let selectivity = filter.count() as f64 / ds.n_base().max(1) as f64;
                for &ef in &ef_grid {
                    let p = crinn::eval::measure_filtered_point(index.as_ref(), &ds, k, ef, filter);
                    eprintln!(
                        "  [{label}] {tier} ef={ef:<4} recall={:.4} qps={:.0}",
                        p.recall, p.qps
                    );
                    let _ = writeln!(
                        csv,
                        "{},{},{},{:.4},{},{},{},{:.6},{:.2},{:.9},{:.9}",
                        ds.name,
                        label,
                        tier,
                        selectivity,
                        filter.count(),
                        k,
                        ef,
                        p.recall,
                        p.qps,
                        p.mean_latency_s,
                        p.p99_latency_s
                    );
                }
            }
        }
    }
    let path = harness::reports_dir().join("filtered_sweep.csv");
    report::save(&path, &csv)?;
    eprintln!("wrote {}", path.display());
    Ok(())
}
