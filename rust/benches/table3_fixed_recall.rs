//! Table 3: QPS at fixed recall levels — CRINN vs the best baseline.
//!
//! For each dataset and recall target ∈ {0.9, 0.95, 0.99, 0.999}:
//! interpolate every system's QPS at the target from its sweep, report
//! CRINN, the best baseline, and the improvement % — the paper's Table 3
//! columns. Rows where no system reaches the target are dropped (the
//! paper's "absent" convention). Output: stdout markdown +
//! `reports/table3_fixed_recall.{md,csv}`.

use crinn::eval::harness;
use crinn::eval::{qps_at_recall, report};
use std::fmt::Write as _;

const TARGETS: [f64; 4] = [0.90, 0.95, 0.99, 0.999];

fn main() {
    let ef_grid = harness::bench_ef_grid();
    let datasets = harness::bench_dataset_names();
    let mut md = String::from(
        "| Dataset | Recall | CRINN QPS | Best Baseline | Baseline QPS | Improvement |\n|---|---|---|---|---|---|\n",
    );
    let mut csv =
        String::from("dataset,recall,crinn_qps,best_baseline,baseline_qps,improvement_pct\n");
    for name in &datasets {
        eprintln!("[table3] dataset {name}");
        let ds = match harness::bench_dataset(name, crinn::DEFAULT_K) {
            Ok(ds) => ds,
            Err(e) => {
                eprintln!("[table3] skipping {name}: {e:#}");
                continue;
            }
        };
        let sweeps: Vec<_> = harness::algorithms()
            .into_iter()
            .map(|(label, builder)| harness::run_algorithm(&ds, label, builder, &ef_grid))
            .collect();
        for &t in &TARGETS {
            let crinn_q = sweeps
                .iter()
                .find(|s| s.index_name == "crinn")
                .and_then(|s| qps_at_recall(&s.points, t));
            let best_baseline = sweeps
                .iter()
                .filter(|s| s.index_name != "crinn")
                .filter_map(|s| qps_at_recall(&s.points, t).map(|q| (q, s.index_name.clone())))
                .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            if let (Some(cq), Some((bq, bname))) = (crinn_q, best_baseline) {
                let imp = (cq / bq - 1.0) * 100.0;
                let _ = writeln!(
                    md,
                    "| {name} | {t:.3} | {cq:.0} | {bname} | {bq:.0} | {imp:+.2}% |"
                );
                let _ = writeln!(csv, "{name},{t},{cq:.1},{bname},{bq:.1},{imp:.2}");
            }
        }
    }
    println!("\n## Table 3 — QPS at fixed recall (sandbox scale)\n\n{md}");
    let dir = harness::reports_dir();
    report::save(&dir.join("table3_fixed_recall.md"), &md).unwrap();
    report::save(&dir.join("table3_fixed_recall.csv"), &csv).unwrap();
    println!("wrote reports/table3_fixed_recall.{{md,csv}}");
}
