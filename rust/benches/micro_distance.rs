//! Micro-bench: distance kernels (f32 vs SQ8) across the Table-2 dims —
//! the innermost hot path of every index, and the first §Perf target.
//! Also times the PJRT batch-scan artifact per 64x4096 block for the
//! batch-path comparison in EXPERIMENTS.md §Perf.

use crinn::distance::{dot, l2_sq, quant::QuantizedStore, Metric};
use crinn::util::bench::{report_row, time_adaptive};
use crinn::util::rng::Rng;
use std::hint::black_box;

fn main() {
    let mut rng = Rng::new(1);
    println!("## micro_distance — per-pair distance kernels\n");
    for &dim in &[25usize, 100, 128, 256, 784, 960] {
        let n = 1024;
        let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
        let store = QuantizedStore::build(&data, dim);
        let qc = store.encode_query(&q);

        let mut i = 0;
        let s = time_adaptive(0.3, 1000, || {
            i = (i + 1) % n;
            black_box(l2_sq(&q, &data[i * dim..(i + 1) * dim]));
        });
        report_row(&format!("l2_sq f32 d={dim}"), &s);
        let flops = 3.0 * dim as f64;
        println!(
            "{:>60}",
            format!("~{:.2} GFLOP/s", flops / s.mean / 1e9)
        );

        let mut i = 0;
        let s = time_adaptive(0.3, 1000, || {
            i = (i + 1) % n;
            black_box(dot(&q, &data[i * dim..(i + 1) * dim]));
        });
        report_row(&format!("dot f32 d={dim}"), &s);

        let mut i = 0;
        let s = time_adaptive(0.3, 1000, || {
            i = (i + 1) % n;
            black_box(store.distance(Metric::L2, &qc, i));
        });
        report_row(&format!("l2 sq8 d={dim}"), &s);
    }

    // PJRT batch scan (one compiled 64x4096 block per call).
    println!("\n## PJRT batch scan artifact (64 x 4096 block)\n");
    match crinn::runtime::Engine::from_default_artifacts() {
        Err(e) => println!("(skipped: {e})"),
        Ok(engine) => {
            for &dim in &[128usize, 960] {
                let q: Vec<f32> = (0..64 * dim).map(|_| rng.next_gaussian_f32()).collect();
                let b: Vec<f32> = (0..4096 * dim).map(|_| rng.next_gaussian_f32()).collect();
                let s = time_adaptive(0.5, 3, || {
                    black_box(engine.scan(Metric::L2, &q, 64, &b, 4096, dim).unwrap());
                });
                report_row(&format!("pjrt scan_l2 d={dim}"), &s);
                let pair_ns = s.mean / (64.0 * 4096.0) * 1e9;
                println!("{:>60}", format!("~{pair_ns:.1} ns/pair amortized"));
            }
        }
    }
}
