//! Micro-bench: distance kernels (portable scalar vs dispatched SIMD vs
//! one-to-many batch, plus SQ8) across the Table-2 dims — the innermost
//! hot path of every index, and the first §Perf target. Also times the
//! PJRT batch-scan artifact per 64x4096 block for the batch-path
//! comparison in EXPERIMENTS.md §Perf.
//!
//! Quick iteration: `make bench-distance` from the repo root runs only
//! this target.

use crinn::anns::store::pq::{self, PqStore};
use crinn::distance::{dot, l2_sq, l2_sq_batch, quant::QuantizedStore, simd, Metric};
use crinn::util::bench::{report_row, time_adaptive};
use crinn::util::rng::Rng;
use std::hint::black_box;

const BATCH: usize = 64;

fn main() {
    let mut rng = Rng::new(1);
    println!(
        "## micro_distance — per-pair distance kernels (dispatch: f32 {}, i8 {}, pq {})\n",
        simd::kernels().name,
        simd::kernels_i8().name,
        simd::kernels_pq().name
    );
    for &dim in &[25usize, 100, 128, 256, 784, 960] {
        let n = 1024;
        let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian_f32()).collect();
        let store = QuantizedStore::build(&data, dim);
        let qc = store.encode_query(&q);

        // Portable scalar reference (what the dispatcher falls back to).
        let mut i = 0;
        let s = time_adaptive(0.3, 1000, || {
            i = (i + 1) % n;
            black_box(simd::portable::l2_sq(&q, &data[i * dim..(i + 1) * dim]));
        });
        report_row(&format!("l2_sq portable d={dim}"), &s);

        // Dispatched SIMD kernel (AVX2+FMA where detected).
        let mut i = 0;
        let s = time_adaptive(0.3, 1000, || {
            i = (i + 1) % n;
            black_box(l2_sq(&q, &data[i * dim..(i + 1) * dim]));
        });
        report_row(&format!("l2_sq simd d={dim}"), &s);
        let flops = 3.0 * dim as f64;
        println!("{:>60}", format!("~{:.2} GFLOP/s", flops / s.mean / 1e9));

        // One-to-many batch kernel over a gathered (shuffled) id list —
        // the HNSW edge-batch / rerank shape. Reported per call; per-pair
        // cost is mean / BATCH.
        let mut ids: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut ids);
        let mut out: Vec<f32> = Vec::with_capacity(BATCH);
        let mut b = 0;
        let s = time_adaptive(0.3, 1000, || {
            b = (b + 1) % (n / BATCH);
            l2_sq_batch(&q, &ids[b * BATCH..(b + 1) * BATCH], &data, dim, &mut out);
            black_box(out.last().copied());
        });
        report_row(&format!("l2_sq_batch x{BATCH} d={dim}"), &s);
        println!(
            "{:>60}",
            format!("~{:.1} ns/pair amortized", s.mean / BATCH as f64 * 1e9)
        );

        let mut i = 0;
        let s = time_adaptive(0.3, 1000, || {
            i = (i + 1) % n;
            black_box(dot(&q, &data[i * dim..(i + 1) * dim]));
        });
        report_row(&format!("dot simd d={dim}"), &s);

        let mut i = 0;
        let s = time_adaptive(0.3, 1000, || {
            i = (i + 1) % n;
            black_box(store.distance(Metric::L2, &qc, i));
        });
        report_row(&format!("l2 sq8 d={dim}"), &s);

        // i8 kernels: portable 32-wide scalar vs dispatched SIMD vs
        // one-to-many batch (the GLASS quantized-beam / IVF posting-list
        // shape). Raw code distances, no scale mapping.
        let mut i = 0;
        let s = time_adaptive(0.3, 1000, || {
            i = (i + 1) % n;
            black_box(simd::portable_i8::l2_sq(&qc, store.code(i)));
        });
        report_row(&format!("l2_i8 portable d={dim}"), &s);

        let mut i = 0;
        let s = time_adaptive(0.3, 1000, || {
            i = (i + 1) % n;
            black_box((simd::kernels_i8().l2_sq)(&qc, store.code(i)));
        });
        report_row(&format!("l2_i8 simd d={dim}"), &s);

        let mut i = 0;
        let s = time_adaptive(0.3, 1000, || {
            i = (i + 1) % n;
            black_box((simd::kernels_i8().dot)(&qc, store.code(i)));
        });
        report_row(&format!("dot_i8 simd d={dim}"), &s);

        let mut qdists: Vec<f32> = Vec::with_capacity(BATCH);
        let mut b = 0;
        let s = time_adaptive(0.3, 1000, || {
            b = (b + 1) % (n / BATCH);
            store.distance_batch(
                Metric::L2,
                &qc,
                &ids[b * BATCH..(b + 1) * BATCH],
                &mut qdists,
            );
            black_box(qdists.last().copied());
        });
        report_row(&format!("l2_i8_batch x{BATCH} d={dim}"), &s);
        println!(
            "{:>60}",
            format!("~{:.1} ns/pair amortized", s.mean / BATCH as f64 * 1e9)
        );

        // 4-bit PQ fast-scan: query→LUT build, per-row ADC (scalar table
        // walk), the dispatched 32-row block kernel over position-major
        // blocks (the IVF posting-list shape), and the gathered batch
        // (the GLASS beam / rerank shape).
        let pq_store = PqStore::build(&data, dim, 16, 1);
        let s = time_adaptive(0.3, 1000, || {
            black_box(pq_store.lut(Metric::L2, &q));
        });
        report_row(&format!("pq lut-build m=16 d={dim}"), &s);

        let lut = pq_store.lut(Metric::L2, &q);
        let mut i = 0;
        let s = time_adaptive(0.3, 1000, || {
            i = (i + 1) % n;
            black_box(pq_store.distance(&lut, i));
        });
        report_row(&format!("pq_adc portable d={dim}"), &s);

        let rb = pq_store.row_bytes();
        let mut blocks: Vec<u8> = Vec::new();
        for r in 0..n {
            pq::scatter_row(&mut blocks, rb, r, pq_store.code(r));
        }
        let n_blocks = blocks.len() / pq::block_bytes(rb);
        let mut sums = [0u32; simd::PQ_BLOCK];
        let mut b = 0;
        let s = time_adaptive(0.3, 1000, || {
            b = (b + 1) % n_blocks;
            let block = &blocks[b * pq::block_bytes(rb)..(b + 1) * pq::block_bytes(rb)];
            (simd::kernels_pq().block)(&lut, block, &mut sums);
            black_box(sums[0]);
        });
        report_row(&format!("pq_adc block32 d={dim}"), &s);
        println!(
            "{:>60}",
            format!("~{:.1} ns/pair amortized", s.mean / simd::PQ_BLOCK as f64 * 1e9)
        );

        let mut pq_out: Vec<f32> = Vec::with_capacity(BATCH);
        let mut b = 0;
        let s = time_adaptive(0.3, 1000, || {
            b = (b + 1) % (n / BATCH);
            pq_store.distance_batch(&lut, &ids[b * BATCH..(b + 1) * BATCH], &mut pq_out);
            black_box(pq_out.last().copied());
        });
        report_row(&format!("pq_adc_batch x{BATCH} d={dim}"), &s);
        println!(
            "{:>60}",
            format!("~{:.1} ns/pair amortized", s.mean / BATCH as f64 * 1e9)
        );
    }

    // PJRT batch scan (one compiled 64x4096 block per call).
    println!("\n## PJRT batch scan artifact (64 x 4096 block)\n");
    match crinn::runtime::Engine::from_default_artifacts() {
        Err(e) => println!("(skipped: {e})"),
        Ok(engine) => {
            for &dim in &[128usize, 960] {
                let q: Vec<f32> = (0..64 * dim).map(|_| rng.next_gaussian_f32()).collect();
                let b: Vec<f32> = (0..4096 * dim).map(|_| rng.next_gaussian_f32()).collect();
                let s = time_adaptive(0.5, 3, || {
                    black_box(engine.scan(Metric::L2, &q, 64, &b, 4096, dim).unwrap());
                });
                report_row(&format!("pjrt scan_l2 d={dim}"), &s);
                let pair_ns = s.mean / (64.0 * 4096.0) * 1e9;
                println!("{:>60}", format!("~{pair_ns:.1} ns/pair amortized"));
            }
        }
    }
}
