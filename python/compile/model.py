"""L2: JAX compute graphs for the CRINN stack (build-time only).

Three families of entry points, all lowered to HLO text by ``aot.py`` and
executed from the Rust coordinator via PJRT:

1. **Batch distance / rerank** — thin wrappers around the L1 Pallas kernels
   (`kernels.distance`). One artifact per (metric, vector-dim) pair the
   benchmark datasets need; the Rust runtime pads query/base blocks to the
   compiled shapes.

2. **Policy network** — the CRINN "generator". The paper's LLM proposes a
   module implementation; our substitution (DESIGN.md §2) is a Gaussian
   policy over the structured variant-knob space. ``policy_forward`` maps
   the contrastive prompt features (exemplar knob-vectors ⊕ scores ⊕ module
   one-hot, mirroring Table 1's structure) to a mean/log-std over the A
   knobs of one module.

3. **GRPO step** — Eq. 3 of the paper: clipped importance-weighted surrogate
   with a KL penalty against the reference policy, over a group of G
   completions with group-normalized advantages (Eq. 2, computed in Rust).
   The whole update (loss -> grad -> Adam) is one fused HLO so the Rust
   trainer does a single PJRT call per optimization step.

Shape constants here are the single source of truth: ``aot.py`` writes them
into ``artifacts/manifest.json`` and the Rust side (`crinn::policy`) reads
them — change them here and everything re-syncs via ``make artifacts``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import distance as dk

# ---------------------------------------------------------------------------
# Shape constants (mirrored into artifacts/manifest.json).
# ---------------------------------------------------------------------------

# Batch-path shapes: Rust pads to these.
QUERY_BATCH = 64      # rows per distance/rerank call
BASE_BLOCK = 4096     # base vectors per scan block
RERANK_CANDS = 128    # candidates per query in the rerank artifact

# Policy shapes.
N_KNOBS = 8           # action dim A: knobs per ANNS module (variants/)
N_EXEMPLARS = 4       # contrastive exemplars embedded in the features
N_MODULES = 3         # construction / search / refinement (§3.5 order)
FEAT_DIM = N_MODULES + N_EXEMPLARS * (N_KNOBS + 1) + 1  # +1: step progress
HIDDEN = 64
GROUP = 8             # G in GRPO (Eq. 3)

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8

# Parameter tree layout (order matters: this is the PJRT argument order).
PARAM_SHAPES = [
    ("w1", (FEAT_DIM, HIDDEN)),
    ("b1", (HIDDEN,)),
    ("w2", (HIDDEN, HIDDEN)),
    ("b2", (HIDDEN,)),
    ("wm", (HIDDEN, N_KNOBS)),
    ("bm", (N_KNOBS,)),
    ("logstd", (N_KNOBS,)),
]
N_PARAMS = len(PARAM_SHAPES)


def init_params(seed: int = 0):
    """He-ish init, returned in PARAM_SHAPES order."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, N_PARAMS)
    out = []
    for (name, shape), k in zip(PARAM_SHAPES, ks):
        if name == "logstd":
            out.append(jnp.full(shape, -1.0, jnp.float32))
        elif len(shape) == 2:
            scale = jnp.sqrt(2.0 / shape[0])
            out.append(scale * jax.random.normal(k, shape, jnp.float32))
        else:
            out.append(jnp.zeros(shape, jnp.float32))
    return out


# ---------------------------------------------------------------------------
# 1. Distance / rerank entry points (call the Pallas kernels).
# ---------------------------------------------------------------------------

def scan_block(q, b, *, metric: str):
    """[QUERY_BATCH, D] x [BASE_BLOCK, D] -> [QUERY_BATCH, BASE_BLOCK]."""
    return (dk.batch_distances(q, b, metric=metric),)


def rerank_block(q, c, *, metric: str):
    """[QUERY_BATCH, D] x [QUERY_BATCH, RERANK_CANDS, D] -> [QB, RC]."""
    return (dk.rerank_distances(q, c, metric=metric),)


# ---------------------------------------------------------------------------
# 2. Policy network.
# ---------------------------------------------------------------------------

def _mlp(params, feats):
    w1, b1, w2, b2, wm, bm, logstd = params
    h = jnp.tanh(feats @ w1 + b1)
    h = jnp.tanh(h @ w2 + b2)
    mean = jnp.tanh(h @ wm + bm)  # knobs live in [-1, 1]; Rust maps to ranges
    return mean, logstd


def policy_forward(*args):
    """params..., feats[G, F] -> (mean[G, A], logstd_broadcast[G, A]).

    Batched over the group so one call serves a whole GRPO rollout; for
    single-candidate inference Rust pads the batch.
    """
    params, feats = list(args[:N_PARAMS]), args[N_PARAMS]
    mean, logstd = _mlp(params, feats)
    return mean, jnp.broadcast_to(logstd, mean.shape)


def _gauss_logp(mean, logstd, actions):
    """Sum of diagonal-Gaussian log-probs over the action dim. -> [G]."""
    var = jnp.exp(2.0 * logstd)
    ll = -0.5 * ((actions - mean) ** 2 / var + 2.0 * logstd + jnp.log(2.0 * jnp.pi))
    return jnp.sum(ll, axis=-1)


def _gauss_kl(mean_p, logstd_p, mean_q, logstd_q):
    """KL(p || q) for diagonal Gaussians, summed over action dim. -> [G]."""
    var_p = jnp.exp(2.0 * logstd_p)
    var_q = jnp.exp(2.0 * logstd_q)
    kl = (logstd_q - logstd_p) + (var_p + (mean_p - mean_q) ** 2) / (2.0 * var_q) - 0.5
    return jnp.sum(kl, axis=-1)


def grpo_loss(params, ref_params, feats, actions, advantages, old_logp,
              clip_eps, kl_beta):
    """Eq. 3: -E[min(ratio * Â, clip(ratio) * Â) - β KL(π‖π_ref)].

    feats [G,F], actions [G,A], advantages [G] (already group-normalized per
    Eq. 2 + smoothing, done in `crinn::grpo`), old_logp [G] from rollout
    time. Scalars clip_eps / kl_beta arrive as 0-d tensors so one artifact
    serves any hyperparameter setting.
    """
    mean, logstd = _mlp(params, feats)
    logp = _gauss_logp(mean, logstd, actions)
    ratio = jnp.exp(logp - old_logp)
    unclipped = ratio * advantages
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * advantages
    surrogate = jnp.minimum(unclipped, clipped)
    ref_mean, ref_logstd = _mlp(ref_params, feats)
    kl = _gauss_kl(mean, jnp.broadcast_to(logstd, mean.shape),
                   ref_mean, jnp.broadcast_to(ref_logstd, ref_mean.shape))
    return -jnp.mean(surrogate - kl_beta * kl)


def grpo_step(*args):
    """One fused GRPO update (loss -> grad -> Adam).

    PJRT argument order:
      params[7], adam_m[7], adam_v[7], ref_params[7],
      feats[G,F], actions[G,A], advantages[G], old_logp[G],
      lr, clip_eps, kl_beta, t (Adam step counter, float)
    Returns: new_params[7] ++ new_m[7] ++ new_v[7] ++ (loss,)
    """
    i = 0
    params = list(args[i:i + N_PARAMS]); i += N_PARAMS
    m = list(args[i:i + N_PARAMS]); i += N_PARAMS
    v = list(args[i:i + N_PARAMS]); i += N_PARAMS
    ref_params = list(args[i:i + N_PARAMS]); i += N_PARAMS
    feats, actions, advantages, old_logp, lr, clip_eps, kl_beta, t = args[i:i + 8]

    loss, grads = jax.value_and_grad(grpo_loss)(
        params, ref_params, feats, actions, advantages, old_logp,
        clip_eps, kl_beta)

    new_params, new_m, new_v = [], [], []
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        step = lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        new_params.append(p - step)
        new_m.append(mi)
        new_v.append(vi)
    return tuple(new_params) + tuple(new_m) + tuple(new_v) + (loss,)


def grpo_example_args():
    """ShapeDtypeStructs for lowering grpo_step."""
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    params = [sd(s, f32) for _, s in PARAM_SHAPES]
    return (
        params * 4  # params, m, v, ref_params
        + [
            sd((GROUP, FEAT_DIM), f32),
            sd((GROUP, N_KNOBS), f32),
            sd((GROUP,), f32),
            sd((GROUP,), f32),
            sd((), f32),
            sd((), f32),
            sd((), f32),
            sd((), f32),
        ]
    )


def policy_example_args():
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    return [sd(s, f32) for _, s in PARAM_SHAPES] + [sd((GROUP, FEAT_DIM), f32)]
