"""L1: Pallas blocked batch-distance kernels.

The compute hotspot of every batch path in the CRINN stack — brute-force
ground truth, IVF coarse assignment, and GLASS exact reranking — is a
(Q, D) x (B, D) distance matrix. We express it as a tiled Pallas kernel:

  * grid over (Q/TQ, B/TB) output tiles;
  * each program stages a TQ x D query tile and a TB x D base tile through
    VMEM (BlockSpec below) and emits a TQ x TB distance tile;
  * squared L2 uses the MXU-friendly matmul form
        ||q - b||^2 = ||q||^2 + ||b||^2 - 2 q.b
    so the inner loop is a (TQ, D) @ (D, TB) contraction on the systolic
    array rather than a subtract-square-reduce chain;
  * angular / inner-product are the same contraction with a different
    epilogue.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's ANNS is a
CPU system; the Pallas tiles here are shaped for a TPU-style memory
hierarchy (VMEM-resident tiles, MXU contraction). On this image we lower
with ``interpret=True`` — mandatory, since real TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute. VMEM footprint per program
at the default tiles (TQ=16, TB=512, D<=960):
    q tile   16*960*4   =  60 KiB
    b tile  512*960*4   = 1.9 MiB
    out     16*512*4    =  32 KiB
comfortably inside a 16 MiB/core VMEM budget; see EXPERIMENTS.md §Perf for
the tile sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. TQ divides the padded query batch (64), TB divides the
# padded base block (4096). D is carried whole per tile: ANNS dims are modest
# (25..960) and carrying D whole avoids a K-loop + accumulator in VMEM.
TILE_Q = 16
TILE_B = 512


def _dist_kernel(q_ref, b_ref, o_ref, *, metric: str):
    """One (TQ, TB) output tile. q_ref: [TQ, D], b_ref: [TB, D]."""
    q = q_ref[...]
    b = b_ref[...]
    # The contraction both metrics share — hits the MXU on real hardware.
    dots = jax.lax.dot_general(
        q,
        b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [TQ, TB]
    if metric == "l2":
        qn = jnp.sum(q * q, axis=1, keepdims=True)  # [TQ, 1]
        bn = jnp.sum(b * b, axis=1, keepdims=True).T  # [1, TB]
        o_ref[...] = qn + bn - 2.0 * dots
    elif metric == "angular":
        o_ref[...] = 1.0 - dots
    elif metric == "ip":
        o_ref[...] = -dots
    else:  # pragma: no cover - guarded by DIST_KERNELS
        raise ValueError(f"unknown metric {metric!r}")


def batch_distances(
    q: jnp.ndarray,
    b: jnp.ndarray,
    *,
    metric: str = "l2",
    tile_q: int = TILE_Q,
    tile_b: int = TILE_B,
) -> jnp.ndarray:
    """Blocked distance matrix. q: [Q, D], b: [B, D] -> [Q, B] float32.

    Q must be divisible by ``tile_q`` and B by ``tile_b`` (the Rust runtime
    pads its batches to the compiled shapes; see runtime/engine.rs).
    """
    qn, d = q.shape
    bn, d2 = b.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    tile_q = min(tile_q, qn)
    tile_b = min(tile_b, bn)
    assert qn % tile_q == 0 and bn % tile_b == 0, (qn, bn, tile_q, tile_b)
    grid = (qn // tile_q, bn // tile_b)
    return pl.pallas_call(
        functools.partial(_dist_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_b, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, bn), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(q, b)


def _rerank_kernel(q_ref, c_ref, o_ref, *, metric: str):
    """Per-query candidate rerank tile. q_ref: [TQ, D], c_ref: [TQ, C, D]."""
    q = q_ref[...]
    c = c_ref[...]
    dots = jnp.einsum("qd,qcd->qc", q, c, preferred_element_type=jnp.float32)
    if metric == "l2":
        qn = jnp.sum(q * q, axis=1, keepdims=True)  # [TQ, 1]
        cn = jnp.sum(c * c, axis=2)  # [TQ, C]
        o_ref[...] = qn + cn - 2.0 * dots
    elif metric == "angular":
        o_ref[...] = 1.0 - dots
    elif metric == "ip":
        o_ref[...] = -dots
    else:  # pragma: no cover
        raise ValueError(f"unknown metric {metric!r}")


def rerank_distances(
    q: jnp.ndarray,
    c: jnp.ndarray,
    *,
    metric: str = "l2",
    tile_q: int = TILE_Q,
) -> jnp.ndarray:
    """Exact rerank distances for gathered candidates.

    q: [Q, D], c: [Q, C, D] -> [Q, C]. Used by the GLASS refinement stage:
    the Rust coordinator gathers the quantized-search survivors' full-
    precision vectors into ``c`` and calls the compiled artifact.
    """
    qn, d = q.shape
    qn2, cc, d2 = c.shape
    assert qn == qn2 and d == d2, (q.shape, c.shape)
    tile_q = min(tile_q, qn)
    assert qn % tile_q == 0
    grid = (qn // tile_q,)
    return pl.pallas_call(
        functools.partial(_rerank_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i: (i, 0)),
            pl.BlockSpec((tile_q, cc, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, cc), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((qn, cc), jnp.float32),
        interpret=True,
    )(q, c)


METRICS = ("l2", "angular", "ip")
