"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package has an entry here with identical
semantics, written in the most obvious jnp form. pytest (and hypothesis
sweeps) assert `assert_allclose(kernel(...), ref(...))` — this is the
build-time gate for the AOT artifacts the Rust runtime executes.

Distance conventions (match `rust/src/distance/`):
  * ``l2``      : squared Euclidean distance (no sqrt — monotone, cheaper,
                  what GLASS/faiss use internally).
  * ``angular`` : ann-benchmarks angular distance ``1 - cos(q, b)``.
                  Vectors are L2-normalized at dataset load, so this is
                  ``1 - <q, b>`` on the unit sphere.
  * ``ip``      : negated inner product (maximum-IP search as a min-distance
                  problem).
"""

from __future__ import annotations

import jax.numpy as jnp


def l2_ref(q: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Squared L2 distances. q: [Q, D], b: [B, D] -> [Q, B]."""
    qn = jnp.sum(q * q, axis=-1, keepdims=True)  # [Q, 1]
    bn = jnp.sum(b * b, axis=-1, keepdims=True).T  # [1, B]
    return qn + bn - 2.0 * (q @ b.T)


def angular_ref(q: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Angular distance 1 - <q,b> for unit vectors. [Q, D], [B, D] -> [Q, B]."""
    return 1.0 - q @ b.T


def ip_ref(q: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Negated inner product. [Q, D], [B, D] -> [Q, B]."""
    return -(q @ b.T)


def rerank_l2_ref(q: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Per-query candidate reranking distances.

    q: [Q, D], c: [Q, C, D] -> [Q, C] squared L2.
    """
    diff = q[:, None, :] - c
    return jnp.sum(diff * diff, axis=-1)


def rerank_angular_ref(q: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """q: [Q, D], c: [Q, C, D] -> [Q, C] angular distance (unit vectors)."""
    return 1.0 - jnp.einsum("qd,qcd->qc", q, c)


DIST_REFS = {
    "l2": l2_ref,
    "angular": angular_ref,
    "ip": ip_ref,
}
