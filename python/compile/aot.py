"""AOT bridge: lower every L2 entry point to HLO *text* artifacts.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 crate binds) rejects (`proto.id() <= INT_MAX`). The
text parser reassigns ids, so text round-trips cleanly. See
DESIGN.md §Hardware-Adaptation at the repo root.

Artifacts written (manifest.json indexes them for the Rust runtime):
  scan_{metric}_d{D}.hlo.txt    [64, D] x [4096, D]    -> [64, 4096]
  rerank_{metric}_d{D}.hlo.txt  [64, D] x [64, 128, D] -> [64, 128]
  policy_fwd.hlo.txt            params.., feats[G,F]   -> mean/logstd [G,A]
  grpo_step.hlo.txt             fused Eq.3 + Adam update

Usage: ``python -m compile.aot --out ../artifacts`` (from python/), or just
``make artifacts`` at the repo root. Python never runs after this.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model

# The six benchmark dimensions of Table 2, plus 64 for examples/tests.
DATASET_DIMS = (25, 64, 100, 128, 256, 784, 960)
METRICS = ("l2", "angular")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def build_all(out_dir: str, dims=DATASET_DIMS, verbose=True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    sd = jax.ShapeDtypeStruct
    f32 = jax.numpy.float32
    manifest = {
        "query_batch": model.QUERY_BATCH,
        "base_block": model.BASE_BLOCK,
        "rerank_cands": model.RERANK_CANDS,
        "n_knobs": model.N_KNOBS,
        "n_exemplars": model.N_EXEMPLARS,
        "n_modules": model.N_MODULES,
        "feat_dim": model.FEAT_DIM,
        "hidden": model.HIDDEN,
        "group": model.GROUP,
        "param_shapes": [[n, list(s)] for n, s in model.PARAM_SHAPES],
        "dims": list(dims),
        "metrics": list(METRICS),
        "artifacts": {},
    }

    def emit(name: str, fn, example_args):
        text = lower_entry(fn, example_args)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = f"{name}.hlo.txt"
        if verbose:
            print(f"  {name:26s} {len(text):>9d} chars", file=sys.stderr)

    for d in dims:
        q = sd((model.QUERY_BATCH, d), f32)
        b = sd((model.BASE_BLOCK, d), f32)
        c = sd((model.QUERY_BATCH, model.RERANK_CANDS, d), f32)
        for metric in METRICS:
            emit(f"scan_{metric}_d{d}",
                 functools.partial(model.scan_block, metric=metric), (q, b))
            emit(f"rerank_{metric}_d{d}",
                 functools.partial(model.rerank_block, metric=metric), (q, c))

    emit("policy_fwd", model.policy_forward, model.policy_example_args())
    emit("grpo_step", model.grpo_step, model.grpo_example_args())

    # Initial policy parameters, flat f32 lists the Rust side can ingest
    # without any tensor library.
    params = model.init_params(seed=0)
    manifest["init_params"] = [
        [float(x) for x in p.reshape(-1)] for p in params
    ]

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--dims", default=None,
                    help="comma-separated vector dims (default: all six)")
    args = ap.parse_args()
    dims = DATASET_DIMS if args.dims is None else tuple(
        int(x) for x in args.dims.split(","))
    m = build_all(args.out, dims=dims)
    print(f"wrote {len(m['artifacts'])} artifacts + manifest.json to {args.out}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
