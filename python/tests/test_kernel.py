"""L1 correctness gate: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/metrics/value ranges; fixed cases pin the exact
shapes the AOT artifacts are compiled at (the ones the Rust runtime will
execute).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import distance as dk
from compile.kernels import ref


RNG = np.random.default_rng(0)


def _rand(shape, scale=1.0, dtype=np.float32):
    return (RNG.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Fixed AOT shapes (what the Rust runtime actually runs).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", dk.METRICS)
@pytest.mark.parametrize("d", [25, 64, 128, 960])
def test_batch_distances_aot_shapes(metric, d):
    q = _rand((64, d))
    b = _rand((4096, d))
    if metric == "angular":
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        b /= np.linalg.norm(b, axis=1, keepdims=True)
    got = dk.batch_distances(jnp.asarray(q), jnp.asarray(b), metric=metric)
    want = ref.DIST_REFS[metric](jnp.asarray(q), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("metric", ["l2", "angular"])
@pytest.mark.parametrize("d", [25, 128])
def test_rerank_aot_shapes(metric, d):
    q = _rand((64, d))
    c = _rand((64, 128, d))
    if metric == "angular":
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        c /= np.linalg.norm(c, axis=2, keepdims=True)
    got = dk.rerank_distances(jnp.asarray(q), jnp.asarray(c), metric=metric)
    rfn = ref.rerank_l2_ref if metric == "l2" else ref.rerank_angular_ref
    want = rfn(jnp.asarray(q), jnp.asarray(c))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Semantic pins.
# ---------------------------------------------------------------------------

def test_l2_is_squared_euclidean():
    q = np.array([[0.0, 0.0], [1.0, 2.0]], np.float32)
    b = np.array([[3.0, 4.0], [1.0, 2.0]], np.float32)
    got = np.asarray(dk.batch_distances(jnp.asarray(q), jnp.asarray(b),
                                        metric="l2", tile_q=1, tile_b=1))
    np.testing.assert_allclose(got, [[25.0, 5.0], [8.0, 0.0]], atol=1e-5)


def test_angular_zero_for_identical_unit_vectors():
    v = _rand((8, 16))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    got = np.asarray(dk.batch_distances(jnp.asarray(v), jnp.asarray(v),
                                        metric="angular", tile_q=8, tile_b=8))
    np.testing.assert_allclose(np.diag(got), 0.0, atol=1e-5)
    assert (got >= -1e-5).all() and (got <= 2.0 + 1e-5).all()


def test_ip_is_negated_dot():
    q = _rand((4, 8))
    b = _rand((4, 8))
    got = np.asarray(dk.batch_distances(jnp.asarray(q), jnp.asarray(b),
                                        metric="ip", tile_q=4, tile_b=4))
    np.testing.assert_allclose(got, -(q @ b.T), rtol=1e-5, atol=1e-5)


def test_unknown_metric_rejected():
    q = jnp.zeros((4, 8))
    with pytest.raises(ValueError):
        dk.batch_distances(q, q, metric="hamming", tile_q=4, tile_b=4)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shape/tiling space.
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    tq=st.sampled_from([1, 2, 4, 8]),
    nq_tiles=st.integers(1, 3),
    tb=st.sampled_from([1, 4, 16, 64]),
    nb_tiles=st.integers(1, 3),
    d=st.integers(1, 70),
    metric=st.sampled_from(list(dk.METRICS)),
    seed=st.integers(0, 2**31 - 1),
)
def test_batch_distances_property(tq, nq_tiles, tb, nb_tiles, d, metric, seed):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((tq * nq_tiles, d)).astype(np.float32)
    b = rng.standard_normal((tb * nb_tiles, d)).astype(np.float32)
    got = dk.batch_distances(jnp.asarray(q), jnp.asarray(b),
                             metric=metric, tile_q=tq, tile_b=tb)
    want = ref.DIST_REFS[metric](jnp.asarray(q), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@settings(max_examples=15, deadline=None)
@given(
    tq=st.sampled_from([1, 2, 4]),
    nq_tiles=st.integers(1, 3),
    c=st.integers(1, 24),
    d=st.integers(1, 48),
    metric=st.sampled_from(["l2", "angular"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rerank_property(tq, nq_tiles, c, d, metric, seed):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((tq * nq_tiles, d)).astype(np.float32)
    cd = rng.standard_normal((tq * nq_tiles, c, d)).astype(np.float32)
    got = dk.rerank_distances(jnp.asarray(q), jnp.asarray(cd),
                              metric=metric, tile_q=tq)
    rfn = ref.rerank_l2_ref if metric == "l2" else ref.rerank_angular_ref
    want = rfn(jnp.asarray(q), jnp.asarray(cd))
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(2, 32))
def test_l2_nonnegative_and_symmetric_on_self(seed, d):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((8, d)).astype(np.float32)
    got = np.asarray(dk.batch_distances(jnp.asarray(x), jnp.asarray(x),
                                        metric="l2", tile_q=8, tile_b=8))
    assert (got >= -1e-3).all()
    np.testing.assert_allclose(got, got.T, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.diag(got), 0.0, atol=1e-4)
