"""L2 tests: policy network shapes, GRPO math vs a numpy re-derivation.

The GRPO step is the piece of the paper's Eq. 2/3 that actually runs as a
compiled artifact, so we verify the fused HLO computation (via the traced
jax function — the same graph aot.py lowers) against an independent numpy
implementation of the clipped surrogate + KL + Adam update.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model


def _params(seed=0):
    return model.init_params(seed)


def _zeros_like(params):
    return [jnp.zeros_like(p) for p in params]


def test_init_params_shapes():
    ps = _params()
    assert len(ps) == model.N_PARAMS
    for p, (name, shape) in zip(ps, model.PARAM_SHAPES):
        assert p.shape == shape, name
        assert p.dtype == jnp.float32


def test_feat_dim_consistent():
    assert model.FEAT_DIM == (model.N_MODULES
                              + model.N_EXEMPLARS * (model.N_KNOBS + 1) + 1)


def test_policy_forward_shapes_and_bounds():
    ps = _params()
    feats = jnp.asarray(np.random.default_rng(0).standard_normal(
        (model.GROUP, model.FEAT_DIM)).astype(np.float32))
    mean, logstd = model.policy_forward(*ps, feats)
    assert mean.shape == (model.GROUP, model.N_KNOBS)
    assert logstd.shape == (model.GROUP, model.N_KNOBS)
    # tanh head: means bounded
    assert (np.abs(np.asarray(mean)) <= 1.0).all()


def test_policy_forward_deterministic():
    ps = _params()
    feats = jnp.ones((model.GROUP, model.FEAT_DIM), jnp.float32)
    m1, _ = model.policy_forward(*ps, feats)
    m2, _ = model.policy_forward(*ps, feats)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


# ---------------------------------------------------------------------------
# Numpy re-derivation of the GRPO objective (Eq. 3).
# ---------------------------------------------------------------------------

def _np_mlp(params, feats):
    w1, b1, w2, b2, wm, bm, logstd = [np.asarray(p, np.float64) for p in params]
    h = np.tanh(feats @ w1 + b1)
    h = np.tanh(h @ w2 + b2)
    return np.tanh(h @ wm + bm), logstd


def _np_grpo_loss(params, ref_params, feats, actions, adv, old_logp,
                  clip_eps, kl_beta):
    mean, logstd = _np_mlp(params, feats)
    var = np.exp(2.0 * logstd)
    logp = np.sum(-0.5 * ((actions - mean) ** 2 / var + 2 * logstd
                          + np.log(2 * np.pi)), axis=-1)
    ratio = np.exp(logp - old_logp)
    unclipped = ratio * adv
    clipped = np.clip(ratio, 1 - clip_eps, 1 + clip_eps) * adv
    surr = np.minimum(unclipped, clipped)
    rmean, rlogstd = _np_mlp(ref_params, feats)
    var_q = np.exp(2.0 * rlogstd)
    kl = np.sum((rlogstd - logstd)
                + (var + (mean - rmean) ** 2) / (2 * var_q) - 0.5, axis=-1)
    return -np.mean(surr - kl_beta * kl)


def _rollout(seed):
    rng = np.random.default_rng(seed)
    feats = rng.standard_normal((model.GROUP, model.FEAT_DIM)).astype(np.float32)
    actions = np.clip(rng.standard_normal(
        (model.GROUP, model.N_KNOBS)), -1, 1).astype(np.float32)
    adv = rng.standard_normal(model.GROUP).astype(np.float32)
    adv = (adv - adv.mean()) / (adv.std() + 1e-6)
    return feats, actions, adv


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_grpo_loss_matches_numpy(seed):
    ps = _params(1)
    ref = _params(2)
    feats, actions, adv = _rollout(seed)
    mean, logstd = model.policy_forward(*ps, jnp.asarray(feats))
    # old_logp from the rollout policy itself => ratio starts at 1.
    var = np.exp(2.0 * np.asarray(logstd, np.float64))
    old_logp = np.sum(-0.5 * ((actions - np.asarray(mean, np.float64)) ** 2 / var
                              + 2 * np.asarray(logstd, np.float64)
                              + np.log(2 * np.pi)), axis=-1).astype(np.float32)
    got = float(model.grpo_loss([jnp.asarray(p) for p in ps],
                                [jnp.asarray(p) for p in ref],
                                jnp.asarray(feats), jnp.asarray(actions),
                                jnp.asarray(adv), jnp.asarray(old_logp),
                                jnp.float32(0.2), jnp.float32(0.01)))
    want = _np_grpo_loss(ps, ref, feats.astype(np.float64), actions, adv,
                         old_logp, 0.2, 0.01)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_grpo_step_improves_surrogate():
    """A few steps with positive advantage on one action should raise the
    log-prob of that action (policy moves toward rewarded knobs)."""
    ps = [jnp.asarray(p) for p in _params(3)]
    ref = [jnp.asarray(p) for p in _params(3)]
    m = _zeros_like(ps)
    v = _zeros_like(ps)
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.standard_normal(
        (model.GROUP, model.FEAT_DIM)).astype(np.float32))
    target = jnp.asarray(np.clip(rng.standard_normal(
        (model.GROUP, model.N_KNOBS)), -1, 1).astype(np.float32))
    adv = jnp.asarray(np.array([2.0, -1, -1, 1.5, -0.5, -0.5, -0.25, -0.25],
                               np.float32))

    def logp_of_target(params):
        mean, logstd = model.policy_forward(*params, feats)
        var = jnp.exp(2.0 * logstd)
        ll = -0.5 * ((target - mean) ** 2 / var + 2.0 * logstd
                     + jnp.log(2.0 * jnp.pi))
        return np.asarray(jnp.sum(ll, axis=-1))

    lp0 = logp_of_target(ps)
    old_logp = jnp.asarray(lp0)
    losses = []
    for t in range(1, 21):
        out = model.grpo_step(*ps, *m, *v, *ref, feats, target, adv, old_logp,
                              jnp.float32(0.02), jnp.float32(0.2),
                              jnp.float32(0.01), jnp.float32(t))
        n = model.N_PARAMS
        ps = list(out[:n])
        m = list(out[n:2 * n])
        v = list(out[2 * n:3 * n])
        losses.append(float(out[-1]))
    lp1 = logp_of_target(ps)
    # Positive-advantage rows get more likely.
    assert lp1[0] > lp0[0]
    assert lp1[3] > lp0[3]
    assert np.isfinite(losses).all()


def test_grpo_step_output_arity():
    ps = [jnp.asarray(p) for p in _params(0)]
    m = _zeros_like(ps)
    v = _zeros_like(ps)
    feats, actions, adv = _rollout(0)
    out = model.grpo_step(*ps, *m, *v, *ps, jnp.asarray(feats),
                          jnp.asarray(actions), jnp.asarray(adv),
                          jnp.zeros(model.GROUP, jnp.float32),
                          jnp.float32(1e-3), jnp.float32(0.2),
                          jnp.float32(0.01), jnp.float32(1.0))
    assert len(out) == 3 * model.N_PARAMS + 1
    for o, p in zip(out[:model.N_PARAMS], ps):
        assert o.shape == p.shape


def test_scan_and_rerank_wrappers_match_kernels():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((4096, 64)).astype(np.float32))
    (d,) = model.scan_block(q, b, metric="l2")
    assert d.shape == (64, 4096)
    qn = np.sum(np.asarray(q) ** 2, 1)[:, None]
    bn = np.sum(np.asarray(b) ** 2, 1)[None, :]
    want = qn + bn - 2 * np.asarray(q) @ np.asarray(b).T
    np.testing.assert_allclose(np.asarray(d), want, rtol=2e-4, atol=2e-4)

    c = jnp.asarray(rng.standard_normal((64, 128, 64)).astype(np.float32))
    (r,) = model.rerank_block(q, c, metric="l2")
    assert r.shape == (64, 128)
