"""AOT bridge tests: artifacts lower, parse as HLO text, manifest is sane.

Lowering the full artifact set takes a little while, so these tests build a
reduced set (one dim) into a tmpdir; the `make artifacts` output is checked
structurally if present.
"""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build_all(str(out), dims=(64,), verbose=False)
    return str(out), manifest


def test_manifest_fields(built):
    out, m = built
    assert m["query_batch"] == model.QUERY_BATCH
    assert m["base_block"] == model.BASE_BLOCK
    assert m["feat_dim"] == model.FEAT_DIM
    assert m["group"] == model.GROUP
    assert [tuple(s) for _, s in
            [(n, tuple(sh)) for n, sh in m["param_shapes"]]]
    assert set(m["metrics"]) == {"l2", "angular"}


def test_expected_artifacts_present(built):
    out, m = built
    names = set(m["artifacts"])
    for metric in ("l2", "angular"):
        assert f"scan_{metric}_d64" in names
        assert f"rerank_{metric}_d64" in names
    assert "policy_fwd" in names
    assert "grpo_step" in names
    for fname in m["artifacts"].values():
        path = os.path.join(out, fname)
        assert os.path.exists(path)
        head = open(path).read(200)
        # HLO text modules start with `HloModule`.
        assert head.startswith("HloModule"), head[:40]


def test_hlo_text_has_entry_computation(built):
    out, m = built
    text = open(os.path.join(out, m["artifacts"]["scan_l2_d64"])).read()
    assert "ENTRY" in text
    # No Mosaic custom-calls may leak into CPU artifacts (interpret=True).
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_init_params_roundtrip(built):
    _, m = built
    flat = m["init_params"]
    assert len(flat) == model.N_PARAMS
    for vals, (_, shape) in zip(flat, model.PARAM_SHAPES):
        n = 1
        for s in shape:
            n *= s
        assert len(vals) == n
        assert all(isinstance(v, float) for v in vals[:3])


def test_manifest_json_roundtrip(built):
    out, _ = built
    with open(os.path.join(out, "manifest.json")) as f:
        m = json.load(f)
    assert m["dims"] == [64]
