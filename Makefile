# Convenience targets. Rust work happens in rust/ (see README.md §Quickstart).

.PHONY: build test test-filtered test-storage test-tune test-pq test-net tune-smoke bench bench-distance bench-filtered bench-restart bench-net artifacts clean

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

bench:
	cd rust && cargo bench

# Quick kernel iteration: only the distance micro-bench (f32 scalar vs
# SIMD vs batch, plus the i8 portable/simd/batch SQ8 rows for
# EXPERIMENTS.md §Perf).
bench-distance:
	cd rust && cargo bench --bench micro_distance

# Filtered-search conformance + property tests (the CI filtered lane).
test-filtered:
	cd rust && CRINN_THREADS=2 cargo test -q filtered && CRINN_THREADS=2 cargo test -q conformance

# Filtered-QPS vs selectivity sweep -> reports/filtered_sweep.csv
# (EXPERIMENTS.md §Filtered-recall).
bench-filtered:
	cd rust && cargo bench --bench filtered_sweep

# Storage-tier suite (the CI storage lane): the paged-snapshot and
# section-directory groups, the region/segment + mutation-log unit
# groups, and the crash-safety/restart property tests.
test-storage:
	cd rust && CRINN_THREADS=2 cargo test -q persist && CRINN_THREADS=2 cargo test -q store && CRINN_THREADS=2 cargo test -q wal

# Self-tuning suite (the CI tune lane): the tuning-space round-trip,
# oracle, Lagrangian-search, and hostile-artifact groups.
test-tune:
	cd rust && CRINN_THREADS=2 cargo test -q tune && CRINN_THREADS=2 cargo test -q variants

# PQ fast-scan suite (the CI pq lane): the 4-bit ADC kernel identity
# groups, PqStore training/persist (incl. hostile PQ sections), and the
# IVF-PQ / GLASS PQ-beam serving modes plus conformance floors.
test-pq:
	cd rust && CRINN_THREADS=2 cargo test -q pq && CRINN_THREADS=2 cargo test -q conformance

# Network-edge suite (the CI serving lane): wire-protocol + admission
# unit groups, the loopback socket integration tests (bitwise identity,
# hostile frames, tenant quotas, deadlines, graceful drain), and the
# coordinator groups they lean on.
test-net:
	cd rust && CRINN_THREADS=2 cargo test -q net && CRINN_THREADS=2 cargo test -q coordinator

# Closed-loop socket vs in-process QPS -> reports/net_qps.csv
# (EXPERIMENTS.md §Net-QPS). CRINN_BENCH_NET_CLIENTS=1,4,16 to override.
bench-net:
	cd rust && cargo bench --bench net_qps

# End-to-end self-tuning smoke: `crinn tune` on a tiny dataset writes a
# checksummed artifact, `crinn serve --tuned` loads it and serves with
# its knobs. Engine-free (--method lagrange), a few seconds total.
tune-smoke:
	cd rust && cargo build --release
	cd rust && CRINN_THREADS=2 ./target/release/crinn tune --dataset demo-64 \
		--n 2000 --queries 40 --evals 8 --floor 0.8 --out /tmp/crinn-tune-smoke.crinn
	cd rust && CRINN_THREADS=2 ./target/release/crinn serve --dataset demo-64 \
		--n 2000 --queries 40 --requests 200 --tuned /tmp/crinn-tune-smoke.crinn
	rm -f /tmp/crinn-tune-smoke.crinn

# Cold-start time + RSS, heap vs mmap serving -> reports/restart.csv
# (EXPERIMENTS.md §Restart). CRINN_BENCH_RESTART_N=100000,1000000 opts
# into the 1M row.
bench-restart:
	cd rust && cargo bench --bench restart

# Lower the L2 JAX graphs + L1 Pallas kernels to HLO text artifacts
# consumed by rust/src/runtime. Needs JAX; see DESIGN.md §Hardware-Adaptation.
artifacts:
	cd python && python -m compile.aot --out ../artifacts

clean:
	cd rust && cargo clean
	rm -rf artifacts reports
